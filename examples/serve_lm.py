"""Batched LM serving: prefill + KV-cache decode (greedy).

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --decode-steps 32
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
