"""Label-constrained discovery over an attributed graph (DESIGN.md §12).

Registers a graph with skewed vertex labels + edge types, then runs:

1. a label-constrained iso query (label classes + allowed-vertex set +
   allowed edge types) with predicate pushdown,
2. the same query with host-side filtering (`label_filter="post"`) —
   byte-identical answer, demonstrably not a cache hit (the filter mode
   is part of the cache key),
3. labeled pattern mining, pushdown vs post — identical patterns, fewer
   candidates materialized under pushdown (the paper's cost metric).

Run: PYTHONPATH=src python examples/labeled_discovery.py
"""
from repro.data.synthetic_graphs import attributed_graph
from repro.service import DiscoveryRequest, DiscoveryService


def main():
    svc = DiscoveryService()
    svc.register_graph(
        "proteins", attributed_graph(n=200, m=900, n_labels=5,
                                     n_edge_labels=2, seed=7))

    iso = dict(
        graph="proteins", workload="iso", k=3,
        q_edges=[[0, 1], [1, 2], [0, 2]], q_labels=[1, 1, 1],
        label_predicate={"vertex_any_of": [1, 2],
                         "q_any_of": [[1, 2], [1], [1, 2]],
                         "edge_any_of": [0]})

    push = svc.query(DiscoveryRequest.from_dict(iso))
    print(f"[iso/pushdown] keys={push.result_keys} "
          f"matches={push.results} candidates={push.stats['candidates']}")

    post = svc.query(DiscoveryRequest.from_dict(
        dict(iso, label_filter="post")))
    print(f"[iso/post]     keys={post.result_keys} cached={post.cached} "
          f"candidates={post.stats['candidates']}")
    assert push.result_keys == post.result_keys, "modes must agree"
    assert not post.cached, "label_filter is part of the cache key"

    pat = dict(graph="proteins", workload="pattern", k=3, m_edges=2,
               label_predicate={"vertex_any_of": [0, 1, 2]})
    p_push = svc.query(DiscoveryRequest.from_dict(pat))
    p_post = svc.query(DiscoveryRequest.from_dict(
        dict(pat, label_filter="post")))
    assert p_push.result_keys == p_post.result_keys
    print(f"[pattern]      supports={p_push.result_keys}  candidates: "
          f"pushdown={p_push.stats['candidates']} vs "
          f"host-filter={p_post.stats['candidates']}")

    # identical spec (any label-set ordering) -> served from cache
    again = svc.query(DiscoveryRequest.from_dict(
        dict(iso, label_predicate={"vertex_any_of": [2, 1],
                                   "q_any_of": [[2, 1], [1], [1, 2]],
                                   "edge_any_of": [0]})))
    print(f"[iso repeat]   cached={again.cached} "
          f"(engine steps total: {svc.engine_steps_total})")


if __name__ == "__main__":
    main()
