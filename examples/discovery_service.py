"""Discovery-service quickstart: concurrent queries + the result cache.

Registers two demo graphs, serves a mixed batch of four workloads through
the round-robin scheduler, then repeats a request to show a cache hit
(zero engine super-steps).

    PYTHONPATH=src python examples/discovery_service.py
"""
import time

import numpy as np

from repro.data.synthetic_graphs import labeled_graph, planted_clique_graph
from repro.service import DiscoveryRequest, DiscoveryService


def main():
    print("registering demo graphs...")
    social = planted_clique_graph(n=300, m=2000, clique_size=8, seed=7)
    cite = labeled_graph(100, 400, 4, seed=11)

    svc = DiscoveryService()
    svc.register_graph("social", social)
    svc.register_graph("cite", cite)

    l0, l1 = int(cite.labels[0]), int(cite.labels[1])
    batch = [
        DiscoveryRequest(graph="social", workload="clique", k=3,
                         request_id="top3-cliques"),
        DiscoveryRequest(graph="social", workload="weighted-clique", k=1,
                         weights=tuple(range(1, social.n + 1)),
                         request_id="heaviest-clique"),
        DiscoveryRequest(graph="cite", workload="iso", k=2,
                         q_edges=((0, 1),), q_labels=(l0, l1),
                         request_id="top-edges"),
        DiscoveryRequest(graph="cite", workload="pattern", m_edges=2, k=2,
                         request_id="frequent-wedges"),
    ]

    print(f"serving a batch of {len(batch)} interleaved queries...")
    t0 = time.time()
    responses = svc.serve(batch)
    dt = time.time() - t0
    for r in responses:
        print(f"  {r.request_id:18s} keys={r.result_keys}  "
              f"steps={r.stats['steps']}  candidates={r.stats['candidates']}")
    print(f"batch served in {dt:.2f}s "
          f"({svc.engine_steps_total} engine super-steps total)")

    print("\nrepeating the first request (cache hit)...")
    steps_before = svc.engine_steps_total
    t0 = time.time()
    again = svc.query(batch[0])
    dt = time.time() - t0
    assert again.cached and svc.engine_steps_total == steps_before
    print(f"  cached={again.cached}, {dt * 1e3:.2f}ms, "
          f"engine steps run: {svc.engine_steps_total - steps_before}")
    print(f"  cache stats: {svc.cache.stats()}")


if __name__ == "__main__":
    main()
