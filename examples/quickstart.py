"""Quickstart: top-k subgraph discovery with Nuri-JAX.

Finds the maximum clique in a synthetic social graph, demonstrating the
paper's three mechanisms (targeted expansion, prioritized expansion,
dominance pruning) and the candidate-count win over the baselines.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.core.exhaustive import nuri_np_clique_candidates
from repro.data.synthetic_graphs import planted_clique_graph


def main():
    print("building a 500-vertex graph with a planted 9-clique...")
    g = planted_clique_graph(n=500, m=3000, clique_size=9, seed=42)

    comp = make_clique_computation(g)
    eng = Engine(comp, EngineConfig(k=3, batch=64, pool_capacity=16384))
    t0 = time.time()
    res = eng.run()
    dt = time.time() - t0

    print(f"\ntop-3 cliques (sizes {list(res.result_keys)}) "
          f"in {dt:.2f}s")
    print(f"  best clique: {comp.describe(res.result_states[0])}")
    print(f"  candidates examined: {res.candidates}  "
          f"(expanded {res.expanded}, pruned {res.pruned})")

    print("\ncomparing against Nuri-NP (no prioritization/pruning)...")
    np_res = nuri_np_clique_candidates(g, max_candidates=2_000_000)
    suffix = "" if np_res["completed"] else "+ (budget hit)"
    print(f"  Nuri-NP candidates: {np_res['candidates']}{suffix}")
    print(f"  reduction from prioritization+pruning: "
          f"{np_res['candidates'] / res.candidates:.1f}x")


if __name__ == "__main__":
    main()
