"""End-to-end LM training driver (deliverable b: the e2e example).

Trains a reduced-config LM for a few hundred steps with checkpointing and
fault tolerance; `--demo-failure` kills and resumes mid-run to show the
restart path.  Scale `--steps/--batch/--seq` up on real hardware.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 60 --demo-failure
"""
import argparse
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--demo-failure", action="store_true")
    args = ap.parse_args()

    ckdir = tempfile.mkdtemp(prefix="repro_ck_")
    if args.demo_failure:
        half = args.steps // 2
        print(f"=== run 1: will fail at step {half} ===")
        try:
            train(args.arch, args.steps, args.batch, args.seq,
                  checkpoint_dir=ckdir, checkpoint_every=10,
                  fail_at_step=half)
        except SystemExit as e:
            print(e)
        print("\n=== run 2: resuming from the last committed checkpoint ===")
        _, losses = train(args.arch, args.steps, args.batch, args.seq,
                          checkpoint_dir=ckdir, checkpoint_every=10,
                          resume=True)
    else:
        _, losses = train(args.arch, args.steps, args.batch, args.seq,
                          checkpoint_dir=ckdir, checkpoint_every=25)
    print(f"\nloss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({len(losses)} steps)")


if __name__ == "__main__":
    main()
