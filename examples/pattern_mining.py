"""Top-k frequent pattern mining (paper §3.3) on a labeled graph.

    PYTHONPATH=src python examples/pattern_mining.py
"""
import time

from repro.core.aggregate import topk_frequent_patterns
from repro.core.patterns import code_vertex_labels
from repro.data.synthetic_graphs import labeled_graph


def main():
    g = labeled_graph(n=200, m=700, n_labels=4, seed=7)
    print(f"graph: {g.n} vertices, {g.num_edges} edges, 4 labels")
    for m_edges in (2, 3):
        t0 = time.time()
        res = topk_frequent_patterns(g, m_edges=m_edges, k=3)
        print(f"\ntop-3 {m_edges}-edge patterns "
              f"({time.time() - t0:.2f}s, {res.candidates} candidates, "
              f"{res.groups_pruned} groups pruned):")
        for sup, code in res.patterns:
            labels = code_vertex_labels(code)
            edges = [(i, j) for i, j, _, _ in code]
            print(f"  support {sup}: edges {edges} labels {labels}")


if __name__ == "__main__":
    main()
