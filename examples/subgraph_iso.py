"""Top-k subgraph isomorphism with index-based pruning (paper §4.3).

    PYTHONPATH=src python examples/subgraph_iso.py
"""
import time

from repro.core.engine import Engine, EngineConfig
from repro.core.iso import build_iso_index, make_iso_computation
from repro.data.synthetic_graphs import labeled_graph


def main():
    g = labeled_graph(n=300, m=1100, n_labels=3, seed=1)
    print(f"graph: {g.n} vertices, {g.num_edges} edges")
    t0 = time.time()
    index = build_iso_index(g, max_hops=3)
    print(f"hop/label/degree index built in {time.time() - t0:.2f}s")

    # query: labeled triangle with a tail
    q_edges = [(0, 1), (1, 2), (0, 2), (2, 3)]
    q_labels = [0, 1, 1, 2]
    comp = make_iso_computation(g, q_edges, q_labels, index)
    t0 = time.time()
    res = Engine(comp, EngineConfig(k=5, batch=64,
                                    pool_capacity=16384)).run()
    print(f"\ntop-5 matches by degree score "
          f"({time.time() - t0:.2f}s, {res.candidates} candidates, "
          f"{res.pruned} pruned):")
    for i in range(5):
        if res.result_keys[i] > -2**31 + 1:
            print(f"  score {int(res.result_keys[i]):>4}: "
                  f"{comp.describe(res.result_states[i])}")


if __name__ == "__main__":
    main()
