"""Sharded multi-device discovery engine (DESIGN.md §11).

Multi-device coverage runs in subprocesses with forced host devices so the
main test process keeps its single device (the rest of the suite assumes
it).  The same tests also exist as in-process variants that activate when
the interpreter already sees multiple devices — the CI ``distributed`` job
runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
to exercise those paths directly on CPU-only runners.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.data.synthetic_graphs import planted_clique_graph
from repro.distributed import ShardedEngine


def _run_forced(prog: str, devices: int = 8, timeout: int = 420):
    """Run ``prog`` in a subprocess with N forced host devices.

    Inherits the full environment (a stripped env hangs JAX/XLA startup in
    sandboxed containers) and overrides only the device flags.
    """
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
        JAX_PLATFORMS="cpu",
        PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(prog)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


def _require_devices(n: int) -> None:
    """Dynamic per-tier skip for the in-process sharded tests: each shard
    tier activates as soon as the interpreter sees enough devices (the
    tier-1 CI job forces 2 host devices, the ``distributed`` job 8)."""
    if len(jax.devices()) < n:
        pytest.skip(f"needs >= {n} devices (force host devices with "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count={n})")


# ----------------------------------------------------------- bound collective
def test_sharded_bound_sync_multi_device():
    """The §4 collective: global k-th best over the *deduplicated* union of
    per-shard result sets."""
    res = _run_forced("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core.engine import make_sharded_bound_sync
        from repro.core.api import NEG
        from repro.distributed import shard_map_compat

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        k = 3
        sync = make_sharded_bound_sync("data", k)
        run = jax.jit(shard_map_compat(
            sync, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=P()))

        def pack(entries):
            # entries: {shard: [(state_tuple, key), ...]}
            states = np.zeros((8, k, 2), np.int32)
            keys = np.full((8, k), NEG, np.int32)
            for i, rows in entries.items():
                for j, (s, key) in enumerate(rows):
                    states[i, j], keys[i, j] = s, key
            return jnp.asarray(states), jnp.asarray(keys)

        # distinct states: plain global 3rd-best of the union
        st, ks = pack({0: [((1, 1), 50), ((2, 2), 10), ((3, 3), 5)],
                       3: [((4, 4), 40), ((5, 5), 30)],
                       7: [((6, 6), 45), ((7, 7), 2)]})
        out = run(st, ks)
        assert int(out) == 40, out   # union sorted: 50, 45, 40, 30, ...

        # the same state in two shards' local sets (deferred parent later
        # rebalanced) must count ONCE: keys [50,50,45,...] dedup to a
        # 3rd-best of 30, not 45
        st, ks = pack({0: [((1, 1), 50), ((2, 2), 10)],
                       3: [((1, 1), 50), ((5, 5), 30)],
                       7: [((6, 6), 45)]})
        out = run(st, ks)
        assert int(out) == 30, out

        # an all-NEG union must stay NEG (no threshold while R not full)
        st, ks = pack({})
        out = run(st, ks)
        assert int(out) == NEG, out
        print("BOUND-SYNC-OK")
    """)
    assert "BOUND-SYNC-OK" in res.stdout, res.stderr[-2000:]


# ------------------------------------------------------------- 1-shard parity
def test_single_shard_is_engine_specialization():
    """ShardedEngine(shards=1) runs on the default device and reproduces
    Engine.run() byte-for-byte — the 1-shard specialization claim."""
    g = planted_clique_graph(n=80, m=300, clique_size=6, seed=1)
    comp = make_clique_computation(g)
    cfg = EngineConfig(k=3, batch=16, pool_capacity=512, max_steps=50_000)
    ref = Engine(comp, cfg).run()
    res = ShardedEngine(comp, dataclasses.replace(cfg, shards=1)).run()
    assert np.array_equal(ref.result_keys, res.result_keys)
    assert np.array_equal(ref.result_states, res.result_states)
    assert res.rebalanced == 0
    assert res.per_shard["spilled"] == [0]


def test_shards_exceeding_devices_rejected():
    with pytest.raises(ValueError, match="exceeds"):
        g = planted_clique_graph(n=40, m=100, clique_size=4, seed=0)
        cfg = EngineConfig(k=1, shards=len(jax.devices()) + 1)
        ShardedEngine(make_clique_computation(g), cfg)


# -------------------------------------------------------- multi-shard parity
_PARITY_PROG = """
    import dataclasses
    import numpy as np
    import jax
    from repro.core.clique import make_clique_computation
    from repro.core.engine import Engine, EngineConfig
    from repro.core.graph import GraphStore
    from repro.core.iso import build_iso_index, make_iso_computation
    from repro.data.synthetic_graphs import (densifying_graph, labeled_graph,
                                             planted_clique_graph)
    from repro.distributed import ShardedEngine

    # shard tiers scale with the forced device count: (1, 2) under 2
    # forced host devices (tier-1), (1, 2, 8) under 8 (CI distributed)
    TIERS = tuple(s for s in (1, 2, 8) if s <= len(jax.devices()))

    def check(comp, cfg, shards_list):
        ref = Engine(comp, cfg).run()
        out = []
        for shards in shards_list:
            res = ShardedEngine(
                comp, dataclasses.replace(cfg, shards=shards)).run()
            assert np.array_equal(ref.result_keys, res.result_keys), (
                shards, ref.result_keys, res.result_keys)
            assert np.array_equal(ref.result_states, res.result_states), \\
                shards
            out.append(res)
        return ref, out

    # clique parity across the shard tiers
    g = planted_clique_graph(n=80, m=300, clique_size=6, seed=1)
    check(make_clique_computation(g),
          EngineConfig(k=3, batch=16, pool_capacity=512, max_steps=50_000),
          TIERS)
    print("CLIQUE-PARITY-OK", flush=True)

    # iso parity across the shard tiers (triangle query, labeled graph)
    gl = labeled_graph(n=60, m=150, n_labels=3, seed=5)
    icomp = make_iso_computation(
        gl, [(0, 1), (1, 2), (0, 2)], [1, 1, 1],
        build_iso_index(gl, max_hops=2))
    check(icomp,
          EngineConfig(k=3, batch=16, pool_capacity=1024, max_steps=50_000),
          TIERS)
    print("ISO-PARITY-OK", flush=True)

    # skewed clique (hot subtree on shard 0 of 2, tiny pools): spill and
    # rebalance must both trigger without breaking parity
    gs = densifying_graph(96, 500, seed=3)
    members = np.arange(0, 24, 2)
    extra = [(int(u), int(v)) for i, u in enumerate(members)
             for v in members[i + 1:]]
    gs = GraphStore.from_edges(
        96, np.concatenate([gs.edge_array, np.array(extra, np.int64)]))
    _, (sres,) = check(
        make_clique_computation(gs),
        EngineConfig(k=3, batch=8, pool_capacity=64, max_steps=50_000),
        (2,))
    assert sres.spilled > 0, "skew scenario never spilled"
    assert sres.refilled > 0
    assert sres.rebalanced > 0, "rebalancer never triggered"
    assert len(sres.per_shard["spilled"]) == 2
    print("REBALANCE-OK", sres.spilled, sres.rebalanced, flush=True)

    # service layer: a shards=2 request threads through compile_request
    # and returns the same payload as the single-device run
    from repro.service import DiscoveryRequest, DiscoveryService
    svc = DiscoveryService()
    svc.register_graph("g", g)
    r1 = svc.query(DiscoveryRequest(graph="g", workload="clique", k=3,
                                    use_cache=False))
    r2 = svc.query(DiscoveryRequest(graph="g", workload="clique", k=3,
                                    shards=2, use_cache=False))
    assert r2.status == "ok", r2.error
    assert r1.result_keys == r2.result_keys
    assert r1.results == r2.results
    print("SERVICE-SHARDS-OK", flush=True)
"""


@pytest.mark.parametrize("devices", [2, 8])
def test_sharded_parity_rebalance_service_multi_device(devices):
    """The forced-host-device count is a parameter: the 2-device variant
    keeps the 2-shard tier of the parity matrix exercised by plain tier-1
    runs, the 8-device variant covers the full 1/2/8 matrix."""
    res = _run_forced(_PARITY_PROG, devices=devices)
    for marker in ("CLIQUE-PARITY-OK", "ISO-PARITY-OK", "REBALANCE-OK",
                   "SERVICE-SHARDS-OK"):
        assert marker in res.stdout, (res.stdout, res.stderr[-3000:])


# --------------------------------- in-process (tier-1 2-dev / distributed 8)
@pytest.mark.parametrize("shards", [2, 8])
def test_sharded_parity_inprocess_multi_device(tmp_path, shards):
    """Same parity claim without a subprocess, plus the disk spill backend:
    per-shard VPQs write to per-shard subdirs and clean up on finalize."""
    _require_devices(shards)
    g = planted_clique_graph(n=80, m=300, clique_size=6, seed=1)
    comp = make_clique_computation(g)
    cfg = EngineConfig(k=3, batch=8, pool_capacity=64, max_steps=50_000,
                       spill="disk", spill_dir=str(tmp_path))
    ref = Engine(comp, dataclasses.replace(cfg, spill="host",
                                           spill_dir=None)).run()
    res = ShardedEngine(comp,
                        dataclasses.replace(cfg, shards=shards)).run()
    assert np.array_equal(ref.result_keys, res.result_keys)
    assert np.array_equal(ref.result_states, res.result_states)
    if shards == 2:   # 8 shards have 8x the pool: nothing overflows
        assert res.spilled > 0
    for i in range(shards):   # leak-free: every run file closed
        sub = tmp_path / f"shard{i}"
        assert not sub.exists() or list(sub.iterdir()) == []
