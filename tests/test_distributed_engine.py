"""Distributed engine pieces: the sharded pruning-bound collective and a
shard_map frontier step lowered on a multi-device mesh (subprocess with
forced host devices so the main test process keeps 1 device)."""
import subprocess
import sys
import textwrap


def test_sharded_bound_sync_and_frontier_step():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core.engine import make_sharded_bound_sync
        from repro.core.api import NEG

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        k = 3
        sync = make_sharded_bound_sync("data", k)

        # per-shard local top-k result keys; global 3rd-best of the union
        local = np.full((8, k), NEG, np.int32)
        local[0] = [50, 10, 5]
        local[3] = [40, 30, NEG]
        local[7] = [45, 2, NEG]
        want_threshold = 40          # union sorted: 50,45,40,30,... → 3rd

        out = jax.jit(jax.shard_map(
            sync, mesh=mesh, in_specs=P("data", None),
            out_specs=P(), check_vma=False))(jnp.asarray(local))
        assert int(out) == want_threshold, out

        # frontier expansion sharded over seeds: lower+compile proof
        from repro.core.clique import make_clique_computation
        from repro.data.synthetic_graphs import densifying_graph
        g = densifying_graph(64, 256, seed=0)
        comp = make_clique_computation(g)
        states, prio, ub = comp.init_frontier()

        def shard_step(states):
            cp, cu = comp.score_children(states)
            local_best = jnp.max(cu)
            global_best = jax.lax.pmax(local_best, "data")
            return cp, global_best

        fn = jax.jit(jax.shard_map(
            shard_step, mesh=mesh, in_specs=P("data", None),
            out_specs=(P("data", None), P()), check_vma=False))
        cp, gb = fn(states)
        assert cp.shape == (64, 64)
        print("SHARDED-ENGINE-OK", int(gb))
    """)
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=300,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert "SHARDED-ENGINE-OK" in res.stdout, res.stderr[-2000:]
