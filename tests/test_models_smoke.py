"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs one forward/train step on CPU — output shapes + no NaNs.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_arch
from repro.launch.train import build_smoke, train
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


@pytest.mark.parametrize("arch_name", ALL_ARCHS)
def test_one_train_step(arch_name):
    params, loss_fn, batch_fn = build_smoke(arch_name, batch=4, seq=64,
                                            seed=0)
    batch = batch_fn(0)
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
    assert np.isfinite(float(loss)), arch_name
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch_name
    opt = init_opt_state(params)
    new_params, opt, m = adamw_update(AdamWConfig(), params, grads, opt)
    # params actually moved, no NaNs
    moved = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert moved > 0
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch_name


@pytest.mark.parametrize("arch_name", ["glm4-9b", "gemma2-9b",
                                       "granite-moe-1b-a400m"])
def test_lm_loss_decreases(arch_name):
    _, losses = train(arch_name, steps=30, batch=8, seq=64, seed=0,
                      log_every=0)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first, (arch_name, first, last)


def test_lm_output_shapes_and_softcap():
    from repro.models import transformer as T
    arch = get_arch("gemma2-9b")
    cfg = arch.make_smoke_cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    logits, cache = jax.jit(lambda p, t: T.prefill(cfg, p, t))(params, tokens)
    assert logits.shape == (2, cfg.vocab_padded)
    assert cache["k"].shape == (cfg.n_layers, 2, 32, cfg.n_kv_heads,
                                cfg.head_dim)
    # final softcap bounds logits
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_softcap + 1e-3


def test_decode_matches_prefill():
    """Decoding token S must equal prefill on S+1 tokens (same cfg)."""
    from repro.models import transformer as T
    cfg = get_arch("gemma2-9b").make_smoke_cfg()
    import dataclasses
    cfg = dataclasses.replace(cfg, q_chunk=1, kv_chunk=1, loss_chunk=1)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0, cfg.vocab)
    full_logits, _ = T.prefill(cfg, params, toks)          # last position: 32
    _, cache = T.prefill(cfg, params, toks[:, :32])
    cache = {k: jnp.zeros((cfg.n_layers, 2, 64, cfg.n_kv_heads,
                           cfg.head_dim), jnp.bfloat16).at[:, :, :32].set(v)
             for k, v in cache.items()}
    dec_logits, _ = T.decode_step(cfg, params, cache, toks[:, 32],
                                  jnp.int32(32))
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=0.1, atol=0.15)


def test_moe_routes_to_multiple_experts():
    from repro.models.moe import MoEConfig, moe_ffn
    k = jax.random.PRNGKey(0)
    cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=16)
    x = jax.random.normal(k, (64, 32))
    rw = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    w1 = jax.random.normal(jax.random.PRNGKey(2), (8, 32, 16)) * 0.1
    w3 = jax.random.normal(jax.random.PRNGKey(3), (8, 32, 16)) * 0.1
    w2 = jax.random.normal(jax.random.PRNGKey(4), (8, 16, 32)) * 0.1
    out, aux = moe_ffn(x, rw, w1, w3, w2, cfg)
    assert out.shape == (64, 32) and np.isfinite(float(aux))
    # different tokens get different expert mixes → outputs differ
    assert float(jnp.std(out)) > 0


def test_equivariance_of_sph_harm_features():
    """MACE invariants are rotation-invariant: rotating positions leaves the
    output unchanged (up to numerics)."""
    from repro.models.equivariant import MACEConfig, mace_forward, \
        mace_param_shapes
    cfg = MACEConfig("m", d_hidden=16, d_in=8, edge_chunks=1)
    shapes = mace_param_shapes(cfg)
    leaves, td = jax.tree.flatten(shapes)
    params = jax.tree.unflatten(td, [
        jax.random.normal(jax.random.PRNGKey(i), s.shape) * 0.05
        for i, s in enumerate(leaves)])
    n, e = 20, 60
    k = jax.random.PRNGKey(5)
    pos = jax.random.normal(k, (n, 3))
    batch = dict(features=jax.random.normal(k, (n, 8)), positions=pos,
                 edge_src=jax.random.randint(k, (e,), 0, n),
                 edge_dst=jax.random.randint(jax.random.PRNGKey(6), (e,),
                                             0, n))
    # rotation about z by 0.7 rad
    c, s = np.cos(0.7), np.sin(0.7)
    rot = jnp.asarray([[c, -s, 0], [s, c, 0], [0, 0, 1]], jnp.float32)
    out1 = mace_forward(cfg, params, batch)
    out2 = mace_forward(cfg, params, {**batch, "positions": pos @ rot.T})
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-3, atol=1e-4)
