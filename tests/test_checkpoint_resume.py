"""Durable discovery runs (DESIGN.md §15): in-process resume parity,
service-layer checkpoint policy, and the serve-loop ``--resume`` path.

The crash-injection suite (``test_fault_injection.py``) proves the
contract across real SIGKILLs; this file carries the cheaper in-process
halves:

* resuming an *intermediate* committed step and continuing produces a
  byte-identical result to the uninterrupted run (engine + 2-shard);
* the checkpoint knobs are excluded from the result-cache key but
  included in the engine-reuse key — both directions, mirroring the
  ``sync_every`` discipline in ``test_stale_bound.py``;
* a resumed query honors the absolute ``step_budget`` exactly and never
  double-counts its pre-crash steps into ``engine_steps_total``;
* ``launch.serve`` restarted with ``resume=True`` finishes a truncated
  checkpointed request with the uninterrupted answer, beating the
  heartbeat as it goes.
"""
import dataclasses
import io
import json
import os

import numpy as np
import pytest

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.data.synthetic_graphs import densifying_graph
from repro.distributed import ShardedEngine
from repro.service import (DiscoveryRequest, DiscoveryService,
                           ValidationError)


def _require_devices(n: int) -> None:
    if len(jax.devices()) < n:
        pytest.skip(f"needs >= {n} devices (force host devices with "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count={n})")


def _assert_result_parity(a, b, ctx=""):
    np.testing.assert_array_equal(a.result_keys, b.result_keys, err_msg=ctx)
    np.testing.assert_array_equal(a.result_states, b.result_states,
                                  err_msg=ctx)
    assert (a.steps, a.candidates, a.expanded, a.pruned, a.spilled,
            a.refilled, a.late_pruned, a.syncs, a.host_syncs) == \
           (b.steps, b.candidates, b.expanded, b.pruned, b.spilled,
            b.refilled, b.late_pruned, b.syncs, b.host_syncs), ctx


# ------------------------------------------------------ engine-level parity
@pytest.mark.parametrize("spill,T", [("host", 1), ("disk", 4)])
def test_resume_intermediate_step_matches_uninterrupted(tmp_path, spill, T):
    """Resume from a NON-final committed step (not the newest) and run to
    completion: byte-identical results and counters."""
    g = densifying_graph(72, 600, seed=2)
    comp = make_clique_computation(g)
    cfg = EngineConfig(k=3, batch=4, pool_capacity=48, max_steps=50_000,
                       spill=spill, spill_dir=str(tmp_path / "s1"),
                       steps_per_sync=T)
    oracle = Engine(comp, cfg).run()
    assert oracle.steps > 20, "workload too short to leave mid-run ckpts"

    ck = str(tmp_path / "ckpt")
    ckcfg = dataclasses.replace(cfg, spill_dir=str(tmp_path / "s2"),
                                checkpoint_every=8, checkpoint_dir=ck)
    durable = Engine(comp, ckcfg).run()
    _assert_result_parity(oracle, durable, "checkpointing perturbed run")

    mgr = CheckpointManager(ck)
    committed = mgr.committed_steps()
    assert len(committed) >= 2
    mid = committed[0]                       # oldest retained, < final
    assert mid < oracle.steps
    reng = Engine(comp, dataclasses.replace(
        ckcfg, spill_dir=str(tmp_path / "s3")))
    st = reng.resume(mgr, step=mid)
    assert st.steps == mid
    while not st.done and st.steps < ckcfg.max_steps:
        reng.step(st, max_inner=ckcfg.max_steps - st.steps)
    _assert_result_parity(oracle, reng.finalize(st),
                          f"resume from step {mid} diverged")


def test_sharded_resume_matches_uninterrupted(tmp_path):
    """2-shard resume: per-shard VPQs and pool_occupancy round-trip."""
    _require_devices(2)
    g = densifying_graph(72, 600, seed=4)
    comp = make_clique_computation(g)
    cfg = EngineConfig(k=3, batch=4, pool_capacity=48, max_steps=50_000,
                       shards=2, sync_every=2, steps_per_sync=2,
                       spill="disk", spill_dir=str(tmp_path / "s1"))
    oracle = ShardedEngine(comp, cfg).run()

    ck = str(tmp_path / "ckpt")
    ckcfg = dataclasses.replace(cfg, spill_dir=str(tmp_path / "s2"),
                                checkpoint_every=8, checkpoint_dir=ck)
    ShardedEngine(comp, ckcfg).run()
    mgr = CheckpointManager(ck)
    mid = mgr.committed_steps()[0]
    reng = ShardedEngine(comp, dataclasses.replace(
        ckcfg, spill_dir=str(tmp_path / "s3")))
    st = reng.resume(mgr, step=mid)
    while not st.done and st.steps < ckcfg.max_steps:
        reng.step(st, max_inner=ckcfg.max_steps - st.steps)
    res = reng.finalize(st)
    _assert_result_parity(oracle, res, f"sharded resume from {mid}")
    assert res.rebalanced == oracle.rebalanced


# --------------------------------------------------------------- cache keys
def test_checkpoint_knobs_excluded_from_result_cache_key(tmp_path):
    """Direction 1: checkpointing is a pure observer, so checkpointed,
    resumed, and plain runs of one query share a result-cache entry."""
    r1 = DiscoveryRequest(graph="g", workload="clique", k=3)
    r2 = dataclasses.replace(r1, checkpoint_every=16,
                             checkpoint_dir=str(tmp_path / "ck"),
                             resume=True)
    assert r1.canonical_spec() == r2.canonical_spec()
    svc = DiscoveryService()
    svc.register_graph("g", densifying_graph(48, 160, seed=3))
    first = svc.query(DiscoveryRequest(graph="g", workload="clique", k=3))
    hit = svc.query(DiscoveryRequest(
        graph="g", workload="clique", k=3, checkpoint_every=8,
        checkpoint_dir=str(tmp_path / "ck2")))
    assert first.status == "ok" and hit.status == "ok", \
        (first.error, hit.error)
    assert not first.cached and hit.cached
    assert first.result_keys == hit.result_keys


def test_checkpoint_knobs_included_in_engine_reuse_key(tmp_path):
    """Direction 2: the checkpoint policy rides EngineConfig, so requests
    with different policies must NOT share a compiled engine."""
    svc = DiscoveryService()
    svc.register_graph("g", densifying_graph(48, 160, seed=3))
    base = dict(graph="g", workload="clique", k=3, use_cache=False)
    svc.query(DiscoveryRequest(**base))
    assert len(svc._engines) == 1
    svc.query(DiscoveryRequest(**base))            # same policy: reused
    assert len(svc._engines) == 1
    svc.query(DiscoveryRequest(**base, checkpoint_every=8,
                               checkpoint_dir=str(tmp_path / "ck")))
    assert len(svc._engines) == 2                  # new policy: new engine
    svc.query(DiscoveryRequest(**base, checkpoint_every=8,
                               checkpoint_dir=str(tmp_path / "ck")))
    assert len(svc._engines) == 2


# ------------------------------------------------------------ service layer
def test_resumed_query_honors_budget_and_step_accounting(tmp_path):
    """A truncated checkpointed query resumed with a larger budget stops
    at the ABSOLUTE budget (pre-crash steps count), reproduces the
    uninterrupted truncation byte-for-byte, and adds only its delta to
    ``engine_steps_total``."""
    g = densifying_graph(64, 256, seed=5)
    ck = str(tmp_path / "ck")
    base = dict(graph="g", workload="clique", k=3, batch=8,
                pool_capacity=64, use_cache=False)

    oracle_svc = DiscoveryService()
    oracle_svc.register_graph("g", g)
    oracle = oracle_svc.query(DiscoveryRequest(**base, step_budget=14))
    assert oracle.terminated == "step_budget"
    assert oracle.stats["steps"] == 14

    svc = DiscoveryService()
    svc.register_graph("g", g)
    part = svc.query(DiscoveryRequest(**base, step_budget=6,
                                      checkpoint_every=4,
                                      checkpoint_dir=ck))
    assert part.terminated == "step_budget" and part.stats["steps"] == 6
    assert CheckpointManager(ck).latest_step() == 6   # terminal ckpt
    assert svc.engine_steps_total == 6

    svc2 = DiscoveryService()
    svc2.register_graph("g", g)
    done = svc2.query(DiscoveryRequest(**base, step_budget=14,
                                       checkpoint_every=4,
                                       checkpoint_dir=ck, resume=True))
    assert done.terminated == "step_budget"
    assert done.stats["steps"] == 14        # absolute, not 6 + 14
    assert svc2.engine_steps_total == 14 - 6, \
        "resumed query double-counted its pre-crash steps"
    assert done.result_keys == oracle.result_keys
    assert done.results == oracle.results
    assert "straggler_steps" in done.stats


def test_resume_with_empty_checkpoint_dir_starts_fresh(tmp_path):
    """resume=True with no committed step is a fresh start, not an error
    (the crash-before-first-commit restart path)."""
    svc = DiscoveryService()
    svc.register_graph("g", densifying_graph(48, 160, seed=3))
    resp = svc.query(DiscoveryRequest(
        graph="g", workload="clique", k=3, use_cache=False,
        checkpoint_every=8, checkpoint_dir=str(tmp_path / "empty"),
        resume=True))
    assert resp.status == "ok", resp.error
    assert resp.terminated == "complete"


def test_checkpoint_request_validation():
    with pytest.raises(ValidationError, match="checkpoint_dir"):
        DiscoveryRequest(graph="g", workload="clique", k=1,
                         checkpoint_every=8).validate(None)
    with pytest.raises(ValidationError, match="checkpoint_dir"):
        DiscoveryRequest(graph="g", workload="clique", k=1,
                         resume=True).validate(None)
    with pytest.raises(ValidationError, match="engine workloads"):
        DiscoveryRequest(graph="g", workload="pattern", k=1,
                         checkpoint_every=8,
                         checkpoint_dir="/tmp/x").validate(None)
    req = DiscoveryRequest.from_dict(dict(
        graph="g", workload="clique", k=1, checkpoint_every="8",
        checkpoint_dir="/tmp/x", resume="true"))
    assert req.checkpoint_every == 8 and req.resume is True


# ------------------------------------------------------------- serve loop
def test_serve_resume_finishes_truncated_request(tmp_path):
    """Kill-and-resume through the serving driver: a checkpointed request
    truncated in one serve process finishes byte-identically in a second
    process started with ``--resume``, and the heartbeat file advances."""
    from repro.launch.serve import serve_discovery
    from repro.runtime.fault_tolerance import Heartbeat

    ck = str(tmp_path / "ck")
    hb = str(tmp_path / "hb")
    base = dict(graph="demo-social", workload="clique", k=3, batch=8,
                pool_capacity=64, use_cache=False, request_id="q1")

    out = io.StringIO()
    serve_discovery(lines=[json.dumps(dict(base, step_budget=400))],
                    out=out)
    oracle = json.loads(out.getvalue().splitlines()[0])
    assert oracle["status"] == "ok"

    out = io.StringIO()
    serve_discovery(
        lines=[json.dumps(dict(base, step_budget=8, checkpoint_every=4,
                               checkpoint_dir=ck))],
        out=out, heartbeat=hb)
    first = json.loads(out.getvalue().splitlines()[0])
    assert first["terminated"] == "step_budget"
    assert not Heartbeat.is_stale(hb, timeout=120)

    # "restart" with --resume: same request line, full budget
    out = io.StringIO()
    serve_discovery(
        lines=[json.dumps(dict(base, step_budget=400, checkpoint_every=4,
                               checkpoint_dir=ck))],
        out=out, resume=True, heartbeat=hb)
    resumed = json.loads(out.getvalue().splitlines()[0])
    assert resumed["status"] == "ok", resumed.get("error")
    assert resumed["result_keys"] == oracle["result_keys"]
    assert resumed["stats"]["steps"] == oracle["stats"]["steps"]
    assert not [d for d in os.listdir(ck) if d.endswith(".tmp")]
