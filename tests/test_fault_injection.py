"""Crash-injection suite for durable discovery runs (DESIGN.md §15).

Each cell runs three real subprocesses through ``tests/fault_harness.py``:

1. **oracle** — the uninterrupted run, no checkpointing;
2. **crash** — the same run with periodic checkpointing, SIGKILLed either
   at a fuzzed host-sync boundary or *inside* a checkpoint commit (tmp
   dir fully written, rename not yet executed — the exact window the
   atomic-commit protocol claims is safe);
3. **resume** — restart with ``resume=True`` from the newest committed
   step (fresh start when the crash preceded the first commit).

The resumed result must be byte-identical to the oracle's — top-k states
and keys AND every counter (steps, candidates, expanded, pruned, spilled,
refilled, late_pruned, syncs, host_syncs, rebalanced).  The kill step is
fuzzed from a seeded RNG inside ``[1, oracle_steps)`` so every run of the
suite exercises a different crash point deterministically per seed.

Shard tiers follow the staleness suite's convention: 2-shard cells skip
unless 2 host devices are visible (the CI ``faults`` job forces 2), the
8-shard cells unless 8 are (the CI ``distributed`` job).  After every
resume the checkpoint dir is leak-checked: no ``step_*.tmp`` dirs may
survive (stale tmps from the kill are swept when the resumed manager
attaches), and the resumed run's spill dir must hold no orphaned run
files once the VPQ closes.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

_HARNESS = os.path.join(os.path.dirname(__file__), "fault_harness.py")


def _require_devices(n: int) -> None:
    if len(jax.devices()) < n:
        pytest.skip(f"needs >= {n} devices (force host devices with "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count={n})")


def _run_child(spec: dict, mode: str, timeout: int = 600):
    """One harness subprocess; returns (returncode, parsed RESULT or None)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(_HARNESS), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    shards = spec.get("shards", 1)
    if shards > 1:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={shards}"
    proc = subprocess.run(
        [sys.executable, _HARNESS, "--spec", json.dumps(spec),
         "--mode", mode],
        capture_output=True, text=True, timeout=timeout, env=env)
    result = None
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT "):
            result = json.loads(line[len("RESULT "):])
    return proc.returncode, result, proc.stderr


def _assert_no_tmp_dirs(ckpt_dir: str):
    leaks = [d for d in os.listdir(ckpt_dir) if d.endswith(".tmp")]
    assert not leaks, f"stale checkpoint tmp dirs leaked: {leaks}"


def _assert_spill_clean(spill_dir: str):
    if not os.path.isdir(spill_dir):
        return
    leaks = [os.path.join(r, f) for r, _, fs in os.walk(spill_dir)
             for f in fs]
    assert not leaks, f"orphaned spill files after close: {leaks}"


def _crash_resume_cycle(tmp_path, spec, kill, second_kill=None):
    """oracle → crash(kill) [→ crash(resume, second_kill)] → resume; the
    resumed result must equal the oracle's in every field.  ``kill`` /
    ``second_kill`` may be dicts or callables of the oracle's step count
    (for fuzzed kill points inside the run's actual span)."""
    spec = dict(spec,
                ckpt_dir=str(tmp_path / "ckpt"),
                spill_dir=str(tmp_path / "spill_oracle"))
    rc, oracle, err = _run_child(spec, "oracle")
    assert rc == 0, err
    assert oracle is not None
    assert any(k > np.iinfo(np.int32).min for k in oracle["result_keys"]), \
        "oracle found nothing — workload too small to test anything"
    steps = oracle["steps"]
    assert steps > spec["checkpoint_every"] + 2, \
        f"run too short ({steps} steps) for checkpoint_every=" \
        f"{spec['checkpoint_every']}"
    if callable(kill):
        kill = kill(steps)
    if callable(second_kill):
        second_kill = second_kill(steps)

    kill_spec = dict(spec, spill_dir=str(tmp_path / "spill_crash"), **kill)
    rc, res, err = _run_child(kill_spec, "crash")
    assert rc == -9, f"crash child did not die by SIGKILL (rc={rc}): {err}"
    assert res is None

    if second_kill is not None:
        again = dict(spec, spill_dir=str(tmp_path / "spill_crash2"),
                     resume=True, **second_kill)
        rc, res, err = _run_child(again, "crash")
        assert rc == -9, f"second crash survived (rc={rc}): {err}"

    resume_spec = dict(spec, spill_dir=str(tmp_path / "spill_resume"))
    rc, resumed, err = _run_child(resume_spec, "resume")
    assert rc == 0, err
    assert resumed == oracle, \
        f"resumed run diverged from oracle:\n{resumed}\nvs\n{oracle}"
    _assert_no_tmp_dirs(spec["ckpt_dir"])
    _assert_spill_clean(resume_spec["spill_dir"])
    return steps


def _fuzz_step(seed: int, lo: int, hi: int) -> int:
    return int(np.random.default_rng(seed).integers(lo, hi))


# --------------------------------------------------------- 1-shard tier
def test_kill_at_fuzzed_step_then_again(tmp_path):
    """clique/host: SIGKILL at a fuzzed step, resume, SIGKILL again later,
    resume again — repeated crashes still converge to the oracle."""
    spec = dict(kind="clique", seed=31, spill="host", shards=1, T=1, K=1,
                checkpoint_every=8)
    _crash_resume_cycle(
        tmp_path, spec,
        lambda steps: {"kill_at_step": _fuzz_step(101, 9, steps - 4)},
        second_kill=lambda steps: {
            "kill_at_step": _fuzz_step(102, steps - 3, steps - 1)})


def test_kill_inside_commit_window(tmp_path):
    """iso/disk, macro-stepped: SIGKILL between tmp-write and rename on
    the 2nd commit — the newest *committed* step (the 1st) restores."""
    spec = dict(kind="iso", seed=32, spill="disk", shards=1, T=4, K=1,
                checkpoint_every=16)
    _crash_resume_cycle(tmp_path, spec, {"kill_in_commit": 2})


def test_kill_before_first_commit_falls_back_fresh(tmp_path):
    """clique/disk: SIGKILL inside the FIRST commit — nothing committed,
    resume must fall back to a fresh start and still match the oracle."""
    spec = dict(kind="clique", seed=33, spill="disk", shards=1, T=2, K=1,
                checkpoint_every=8)
    _crash_resume_cycle(tmp_path, spec, {"kill_in_commit": 1})


def test_kill_at_step_weighted_clique(tmp_path):
    """weighted-clique/disk: fuzzed mid-run SIGKILL on the third workload
    family (widest state layout: two bitsets + two weights)."""
    spec = dict(kind="weighted-clique", seed=34, spill="disk", shards=1,
                T=2, K=1, checkpoint_every=8)
    _crash_resume_cycle(
        tmp_path, spec,
        lambda steps: {"kill_at_step": _fuzz_step(104, 9, steps - 1)})


# --------------------------------------------------------- 2-shard tier
def test_kill_at_step_2shards(tmp_path):
    """iso × 2 shards with stale bounds (K=2) and macro-steps (T=2):
    per-shard VPQ snapshots + the merged manifest restore together."""
    _require_devices(2)
    spec = dict(kind="iso", seed=35, spill="disk", shards=2, T=2, K=2,
                checkpoint_every=8)
    _crash_resume_cycle(
        tmp_path, spec,
        lambda steps: {"kill_at_step": _fuzz_step(105, 9, steps - 1)})


def test_kill_inside_commit_2shards(tmp_path):
    """clique × 2 shards, host spill: mid-commit SIGKILL with sharded
    state — the per-shard subdirs commit or vanish atomically together."""
    _require_devices(2)
    spec = dict(kind="clique", seed=36, spill="host", shards=2, T=1, K=4,
                checkpoint_every=8)
    _crash_resume_cycle(tmp_path, spec, {"kill_in_commit": 2})


# --------------------------------------------------------- 8-shard tier
def test_kill_at_step_8shards(tmp_path):
    """clique × 8 shards (CI ``distributed`` job): fuzzed mid-run kill."""
    _require_devices(8)
    spec = dict(kind="clique", seed=37, spill="disk", shards=8, T=2, K=2,
                checkpoint_every=8)
    _crash_resume_cycle(
        tmp_path, spec,
        lambda steps: {"kill_at_step": _fuzz_step(107, 9, steps - 1)})


def test_kill_inside_commit_8shards(tmp_path):
    """weighted-clique × 8 shards: mid-commit kill at scale."""
    _require_devices(8)
    spec = dict(kind="weighted-clique", seed=38, spill="host", shards=8,
                T=1, K=1, checkpoint_every=8)
    _crash_resume_cycle(tmp_path, spec, {"kill_in_commit": 2})
