"""Intra-repo markdown links must resolve (run by the CI docs job).

Scans every root-level ``*.md`` plus ``docs/*.md`` for inline links and
asserts that each relative target exists on disk, so DESIGN.md/README.md/
docs cross-references can't rot silently when files move.
"""
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOCS = sorted(REPO.glob("*.md")) + sorted((REPO / "docs").glob("*.md"))
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:")


def _relative_targets(md: pathlib.Path):
    for target in LINK.findall(md.read_text()):
        if target.startswith(EXTERNAL) or target.startswith("#"):
            continue
        yield target.split("#", 1)[0]


@pytest.mark.parametrize("md", DOCS, ids=lambda p: str(p.relative_to(REPO)))
def test_markdown_links_resolve(md):
    broken = [t for t in _relative_targets(md)
              if not (md.parent / t).exists()]
    assert not broken, f"{md.relative_to(REPO)}: broken links {broken}"


def test_docs_corpus_found():
    names = {p.name for p in DOCS}
    assert {"README.md", "DESIGN.md"} <= names
    assert any(p.parent.name == "docs" for p in DOCS), "docs/*.md missing"
