"""Discovery service: validation, cache determinism, eviction, scheduling."""
import numpy as np
import pytest

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.data.synthetic_graphs import labeled_graph, planted_clique_graph
from repro.service import (DiscoveryRequest, DiscoveryService, GraphRegistry,
                           ResultCache, ValidationError, make_cache_key)


@pytest.fixture(scope="module")
def social():
    return planted_clique_graph(n=80, m=300, clique_size=6, seed=1)


@pytest.fixture(scope="module")
def cite():
    return labeled_graph(40, 120, 3, seed=2)


def make_service(social, cite, **kw):
    svc = DiscoveryService(**kw)
    svc.register_graph("social", social)
    svc.register_graph("cite", cite)
    return svc


# ------------------------------------------------------------- validation
def test_rejects_unknown_workload(social, cite):
    svc = make_service(social, cite)
    resp = svc.query(DiscoveryRequest(graph="social", workload="motif"))
    assert resp.status == "error"
    assert "workload" in resp.error


def test_rejects_bad_k_and_budgets(social, cite):
    svc = make_service(social, cite)
    assert svc.query(DiscoveryRequest(
        graph="social", workload="clique", k=0)).status == "error"
    assert svc.query(DiscoveryRequest(
        graph="social", workload="clique", step_budget=0)).status == "error"
    assert svc.query(DiscoveryRequest(
        graph="social", workload="clique",
        candidate_budget=-5)).status == "error"


def test_rejects_unknown_graph_and_missing_params(social, cite):
    svc = make_service(social, cite)
    assert svc.query(DiscoveryRequest(
        graph="nope", workload="clique")).status == "error"
    # weighted-clique without weights / wrong length
    assert svc.query(DiscoveryRequest(
        graph="social", workload="weighted-clique")).status == "error"
    assert svc.query(DiscoveryRequest(
        graph="social", workload="weighted-clique",
        weights=(1, 2, 3))).status == "error"
    # iso on an unlabeled graph
    assert svc.query(DiscoveryRequest(
        graph="social", workload="iso", q_edges=((0, 1),),
        q_labels=(0, 1))).status == "error"
    # pattern without m_edges
    assert svc.query(DiscoveryRequest(
        graph="cite", workload="pattern")).status == "error"


def test_from_dict_rejects_unknown_fields():
    with pytest.raises(ValidationError):
        DiscoveryRequest.from_dict(
            dict(graph="g", workload="clique", frobnicate=1))


# --------------------------------------------------------- cache key/LRU/TTL
def test_cache_key_deterministic(social):
    r1 = DiscoveryRequest(graph="social", workload="clique", k=3)
    r2 = DiscoveryRequest(graph="social", workload="clique", k=3,
                          request_id="different-id", use_cache=False)
    # same semantic spec -> same key (plumbing fields are excluded)
    k1 = make_cache_key(social.fingerprint, r1.canonical_spec())
    k2 = make_cache_key(social.fingerprint, r2.canonical_spec())
    assert k1 == k2
    # different k -> different key
    r3 = DiscoveryRequest(graph="social", workload="clique", k=4)
    assert make_cache_key(social.fingerprint, r3.canonical_spec()) != k1


def test_cache_key_covers_graph_and_query_graph(social, cite):
    req = DiscoveryRequest(graph="g", workload="clique", k=2)
    assert make_cache_key(social.fingerprint, req.canonical_spec()) != \
        make_cache_key(cite.fingerprint, req.canonical_spec())
    # iso edge order is canonicalized: (0,1),(1,2) == (2,1),(1,0)
    a = DiscoveryRequest(graph="g", workload="iso",
                         q_edges=((0, 1), (1, 2)), q_labels=(0, 1, 0))
    b = DiscoveryRequest(graph="g", workload="iso",
                         q_edges=((2, 1), (1, 0)), q_labels=(0, 1, 0))
    assert a.canonical_spec() == b.canonical_spec()


def test_lru_eviction():
    cache = ResultCache(capacity=2, ttl_s=1e9)
    cache.put("a", 1)
    cache.put("b", 2)
    assert cache.get("a") == 1          # a is now most recently used
    cache.put("c", 3)                   # evicts b (LRU)
    assert cache.get("b") is None
    assert cache.get("a") == 1
    assert cache.get("c") == 3
    assert cache.evictions == 1


def test_ttl_expiry():
    now = [0.0]
    cache = ResultCache(capacity=8, ttl_s=10.0, clock=lambda: now[0])
    cache.put("a", 1)
    now[0] = 5.0
    assert cache.get("a") == 1
    now[0] = 10.1
    assert cache.get("a") is None
    assert cache.expirations == 1


# ------------------------------------------------------- scheduled execution
def test_interleaved_matches_sequential(social):
    """Two concurrent clique queries return byte-identical result_keys to
    dedicated Engine.run() calls (acceptance criterion)."""
    svc = DiscoveryService()
    svc.register_graph("social", social)
    reqs = [DiscoveryRequest(graph="social", workload="clique", k=3,
                             use_cache=False),
            DiscoveryRequest(graph="social", workload="clique", k=1,
                             batch=32, use_cache=False)]
    resps = svc.serve(reqs)

    comp = make_clique_computation(social)
    ref0 = Engine(comp, EngineConfig(k=3)).run()
    ref1 = Engine(comp, EngineConfig(k=1, batch=32)).run()
    assert resps[0].result_keys == [int(x) for x in ref0.result_keys]
    assert resps[1].result_keys == [int(x) for x in ref1.result_keys]
    assert resps[0].stats["candidates"] == ref0.candidates
    assert all(r.terminated == "complete" for r in resps)


def test_cache_hit_runs_zero_engine_steps(social, cite):
    """A repeated identical request is served from the cache without any
    engine super-steps (acceptance criterion, via the step counter)."""
    svc = make_service(social, cite)
    req = DiscoveryRequest(graph="social", workload="clique", k=2)
    first = svc.query(req)
    assert not first.cached and svc.engine_steps_total > 0
    steps_before = svc.engine_steps_total
    second = svc.query(req)
    assert second.cached
    assert svc.engine_steps_total == steps_before
    assert second.result_keys == first.result_keys
    assert second.results == first.results


def test_candidate_budget_terminates_early(social):
    svc = DiscoveryService()
    svc.register_graph("social", social)
    resp = svc.query(DiscoveryRequest(
        graph="social", workload="clique", k=1, candidate_budget=100,
        use_cache=False))
    assert resp.status == "ok"
    assert resp.terminated == "candidate_budget"


def test_mixed_workload_batch(social, cite):
    """clique + pattern + iso interleave in one batch and all complete."""
    svc = make_service(social, cite)
    l0, l1 = int(cite.labels[0]), int(cite.labels[1])
    reqs = [
        DiscoveryRequest(graph="social", workload="clique", k=2),
        DiscoveryRequest(graph="cite", workload="pattern", m_edges=2, k=2),
        DiscoveryRequest(graph="cite", workload="iso", k=2,
                         q_edges=((0, 1),), q_labels=(l0, l1)),
    ]
    resps = svc.serve(reqs)
    assert [r.status for r in resps] == ["ok"] * 3
    for r in resps:
        assert r.result_keys, f"{r.workload} returned no results"
        assert len(r.results) == len(
            [k for k in r.result_keys if k > np.iinfo(np.int32).min])
