"""Fused macro-step engine (DESIGN.md §13) + vectorized VPQ merge.

Macro-stepping is a pure dispatch optimization: `steps_per_sync = T` fuses
up to T super-steps into one jitted while_loop between host syncs.  The
contract tested here: complete runs are byte-identical for any T (and any
shard count), step budgets truncate at exactly the same step count for any
T, the overflow accumulator early-exit preserves parity, and the vectorized
blockwise VPQ merge reproduces the per-entry heap merge byte-for-byte.

The sharded variants are parameterized by shard count and skip per-tier on
the visible device count: the 2-shard tier runs wherever >= 2 host devices
are forced (the tier-1 CI job forces 2), and the 8-shard tier runs in the
CI ``distributed`` job under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import dataclasses
import heapq

import numpy as np
import pytest

import jax

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.core.iso import build_iso_index, make_iso_computation
from repro.core.vpq import NEG, VirtualPriorityQueue
from repro.core.weighted_clique import make_weighted_clique_computation
from repro.data.synthetic_graphs import (densifying_graph, labeled_graph,
                                         planted_clique_graph)


@pytest.fixture(scope="module")
def clique_setup():
    """Dense graph + tiny pool: spill, refill, and late pruning all occur."""
    g = densifying_graph(96, 900, seed=0)
    comp = make_clique_computation(g)
    cfg = EngineConfig(k=3, batch=8, pool_capacity=128, max_steps=100_000)
    ref = Engine(comp, cfg).run()
    assert ref.spilled > 0 and ref.refilled > 0   # the regime under test
    return comp, cfg, ref


def _assert_parity(ref, res):
    assert np.array_equal(ref.result_keys, res.result_keys)
    assert np.array_equal(ref.result_states, res.result_states)


# ------------------------------------------------------------ fused parity
@pytest.mark.parametrize("spill", ["host", "disk"])
@pytest.mark.parametrize("T", [2, 16])
def test_clique_macro_parity(clique_setup, tmp_path, spill, T):
    comp, cfg, ref = clique_setup
    res = Engine(comp, dataclasses.replace(
        cfg, steps_per_sync=T, spill=spill,
        spill_dir=str(tmp_path) if spill == "disk" else None)).run()
    _assert_parity(ref, res)
    assert res.host_syncs < res.steps       # fusion actually amortized
    assert res.syncs == 0                   # single-device: no collectives
    assert res.late_pruned == ref.late_pruned


@pytest.mark.parametrize("spill", ["host", "disk"])
def test_iso_macro_parity(tmp_path, spill):
    gl = labeled_graph(n=60, m=220, n_labels=3, seed=5)
    comp = make_iso_computation(
        gl, [(0, 1), (1, 2), (0, 2)], [1, 1, 1],
        build_iso_index(gl, max_hops=2))
    cfg = EngineConfig(k=3, batch=4, pool_capacity=32, max_steps=100_000,
                       spill=spill,
                       spill_dir=str(tmp_path) if spill == "disk" else None)
    ref = Engine(comp, cfg).run()
    res = Engine(comp, dataclasses.replace(cfg, steps_per_sync=16)).run()
    _assert_parity(ref, res)
    assert res.host_syncs < res.steps or res.steps <= 1


def test_weighted_clique_macro_parity():
    g = densifying_graph(50, 180, seed=3)
    weights = np.random.default_rng(3).integers(1, 20, g.n)
    comp = make_weighted_clique_computation(g, weights)
    cfg = EngineConfig(k=2, batch=8, pool_capacity=64, max_steps=50_000)
    ref = Engine(comp, cfg).run()
    res = Engine(comp, dataclasses.replace(cfg, steps_per_sync=8)).run()
    _assert_parity(ref, res)


# -------------------------------------------------- accumulator early exit
def test_overflow_accumulator_fill_early_exits(clique_setup):
    """A minimum-capacity accumulator forces the fused loop back to the
    host whenever a step spilled — more syncs, identical results."""
    comp, cfg, ref = clique_setup
    full = Engine(comp, dataclasses.replace(cfg, steps_per_sync=16)).run()
    tight = Engine(comp, dataclasses.replace(
        cfg, steps_per_sync=16, overflow_accum=1)).run()   # raised to B+M
    _assert_parity(ref, full)
    _assert_parity(ref, tight)
    # the tight accumulator cannot hold two blocks, so every spilling step
    # ends its macro window: strictly more host syncs than the full-size
    # run, but still fewer than one per step (non-spilling stretches fuse)
    assert tight.host_syncs > full.host_syncs
    assert tight.host_syncs < tight.steps
    assert tight.spilled == ref.spilled


# ------------------------------------------------------- budget exactness
def test_max_steps_truncates_identically(clique_setup):
    comp, cfg, ref = clique_setup
    assert ref.steps > 12
    for T in (1, 4, 16):
        res = Engine(comp, dataclasses.replace(
            cfg, max_steps=12, steps_per_sync=T)).run()
        assert res.steps == 12, f"T={T}: ran {res.steps} steps, not 12"


def test_service_step_budget_truncates_identically(clique_setup):
    from repro.service import DiscoveryRequest, DiscoveryService
    comp, cfg, ref = clique_setup
    svc = DiscoveryService()
    svc.register_graph("g", densifying_graph(96, 900, seed=0))
    for T in (1, 4, 16):
        resp = svc.query(DiscoveryRequest(
            graph="g", workload="clique", k=3, batch=8, pool_capacity=128,
            step_budget=7, steps_per_sync=T, use_cache=False))
        assert resp.status == "ok", resp.error
        assert resp.terminated == "step_budget"
        assert resp.stats["steps"] == 7, f"T={T}: {resp.stats['steps']}"


# ------------------------------------------------------------- service layer
def test_steps_per_sync_service_contract(clique_setup):
    """Excluded from the result-cache key (complete runs are T-invariant),
    validated >= 1, ignored by pattern, and late_pruned is surfaced."""
    from repro.service import DiscoveryRequest, DiscoveryService
    r1 = DiscoveryRequest(graph="g", workload="clique", k=3)
    r2 = DiscoveryRequest(graph="g", workload="clique", k=3,
                          steps_per_sync=16)
    assert r1.canonical_spec() == r2.canonical_spec()

    svc = DiscoveryService()
    svc.register_graph("g", densifying_graph(96, 900, seed=0))
    bad = svc.query(DiscoveryRequest(graph="g", workload="clique",
                                     steps_per_sync=0))
    assert bad.status == "error" and "steps_per_sync" in bad.error

    a = svc.query(DiscoveryRequest(graph="g", workload="clique", k=3,
                                   batch=8, pool_capacity=128,
                                   use_cache=False))
    b = svc.query(DiscoveryRequest(graph="g", workload="clique", k=3,
                                   batch=8, pool_capacity=128,
                                   steps_per_sync=16, use_cache=False))
    assert a.result_keys == b.result_keys and a.results == b.results
    assert a.stats["late_pruned"] > 0          # spilling regime: audited
    assert a.stats["late_pruned"] == b.stats["late_pruned"]


def test_pattern_accepts_and_ignores_steps_per_sync():
    from repro.service import DiscoveryRequest, DiscoveryService
    svc = DiscoveryService()
    svc.register_graph("cite", labeled_graph(40, 120, 3, seed=2))
    base = svc.query(DiscoveryRequest(graph="cite", workload="pattern",
                                      m_edges=2, k=2, use_cache=False))
    fused = svc.query(DiscoveryRequest(graph="cite", workload="pattern",
                                       m_edges=2, k=2, steps_per_sync=16,
                                       use_cache=False))
    assert base.status == fused.status == "ok"
    assert base.result_keys == fused.result_keys
    assert base.results == fused.results
    assert fused.stats["late_pruned"] == 0


# ------------------------------------------------- vectorized VPQ merge
def _heap_pop_chunk(vpq, n, min_ub=NEG):
    """The pre-vectorization per-entry heap merge, kept as the reference
    semantics for the blockwise merge (priority desc, run-index tie-break,
    stop at the n-th surviving entry)."""
    vpq._flush_pending()
    heap = []
    for i, r in enumerate(vpq.runs):
        if not r.exhausted:
            heapq.heappush(heap, (-r.head_prio(), i))
    out_s, out_p, out_u = [], [], []
    while heap and len(out_p) < n:
        _, i = heapq.heappop(heap)
        state, p, u = vpq.runs[i].pop()
        if u >= min_ub:
            out_s.append(state)
            out_p.append(p)
            out_u.append(u)
        if not vpq.runs[i].exhausted:
            heapq.heappush(heap, (-vpq.runs[i].head_prio(), i))
    vpq.runs = [r for r in vpq.runs if not r.exhausted]
    if not out_p:
        return (np.zeros((0, vpq.state_width), np.int32),
                np.zeros((0,), np.int32), np.zeros((0,), np.int32))
    return (np.stack(out_s).astype(np.int32),
            np.asarray(out_p, np.int32), np.asarray(out_u, np.int32))


def test_vectorized_pop_chunk_matches_heap_merge():
    """Fuzz: tie-heavy priorities, ragged buffers, pruning thresholds,
    partial chunks — the blockwise merge must be byte-identical to the
    per-entry heap merge, including how much it leaves in the queue."""
    rng = np.random.default_rng(7)
    for trial in range(40):
        n_entries = int(rng.integers(1, 300))
        frag = int(rng.integers(2, 9))
        bufsz = int(rng.integers(2, 24))
        prios = rng.integers(-4, 4, n_entries).astype(np.int32)
        ubs = rng.integers(-4, 4, n_entries).astype(np.int32)
        states = rng.integers(0, 99, (n_entries, 3)).astype(np.int32)

        def build():
            v = VirtualPriorityQueue(state_width=3, backend="host",
                                     buffer_size=bufsz, run_flush_size=1)
            for i in range(0, n_entries, frag):
                sl = slice(i, i + frag)
                v.maybe_push(states[sl], prios[sl], ubs[sl])
                v._flush_pending()
            return v

        vec, ref = build(), build()
        while len(vec) or len(ref):
            chunk = int(rng.integers(1, 48))
            mu = int(rng.integers(-5, 5))
            got = vec.pop_chunk(chunk, min_ub=mu)
            want = _heap_pop_chunk(ref, chunk, min_ub=mu)
            for a, b in zip(got, want):
                assert np.array_equal(a, b), (trial, chunk, mu)
            assert len(vec) == len(ref), trial


def test_late_pruned_counter():
    vpq = VirtualPriorityQueue(state_width=2, backend="host",
                               run_flush_size=8)
    prio = np.arange(32, dtype=np.int32)
    states = np.stack([prio, prio], 1).astype(np.int32)
    vpq.maybe_push(states, prio, prio.copy())
    _, got, _ = vpq.pop_chunk(32, min_ub=20)   # 0..19 dominated
    assert list(got) == list(range(31, 19, -1))
    assert vpq.total_late_pruned == 20
    assert len(vpq) == 0


# ----------------------------------- sharded (any multi-device interpreter)
# Parameterized by shard count with a *dynamic* skip: each tier activates
# as soon as the interpreter sees enough devices, so the 2-shard tier runs
# under the tier-1 job's 2 forced host devices and only the 8-shard tier
# waits for the CI ``distributed`` job's 8.
def _require_devices(n: int) -> None:
    if len(jax.devices()) < n:
        pytest.skip(f"needs >= {n} devices (force host devices with "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count={n})")


@pytest.mark.parametrize("shards", [1, 2, 8])
def test_sharded_macro_parity_inprocess(clique_setup, shards):
    """Fused sharded runs reproduce the unfused single-device result at
    every shard count; the §4 bound exchange inside the fused loop keeps
    pruning tight, and the global exit vote keeps refill / rebalance
    cadence — spill accounting matches the unfused run."""
    _require_devices(shards)
    from repro.distributed import ShardedEngine
    comp, cfg, ref = clique_setup
    for T in (4, 16):
        res = ShardedEngine(comp, dataclasses.replace(
            cfg, shards=shards, steps_per_sync=T)).run()
        _assert_parity(ref, res)
        assert res.host_syncs < res.steps or res.steps <= 1
        assert res.syncs == res.steps       # K=1: one exchange per step
        unfused = ShardedEngine(comp, dataclasses.replace(
            cfg, shards=shards)).run()
        assert res.spilled == unfused.spilled
        assert res.late_pruned == unfused.late_pruned


def test_sharded_macro_disk_spill_cleanup(tmp_path):
    _require_devices(2)
    g = planted_clique_graph(n=80, m=300, clique_size=6, seed=1)
    comp = make_clique_computation(g)
    cfg = EngineConfig(k=3, batch=8, pool_capacity=64, max_steps=50_000,
                       spill="disk", spill_dir=str(tmp_path),
                       steps_per_sync=16)
    ref = Engine(comp, dataclasses.replace(
        cfg, spill="host", spill_dir=None, steps_per_sync=1)).run()
    from repro.distributed import ShardedEngine
    res = ShardedEngine(comp, dataclasses.replace(cfg, shards=2)).run()
    _assert_parity(ref, res)
    assert res.spilled > 0
    for i in range(2):       # leak-free: every run file closed
        sub = tmp_path / f"shard{i}"
        assert not sub.exists() or list(sub.iterdir()) == []
