"""Substrate: checkpoint atomic-commit protocol, fault-tolerance runtime,
data determinism."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import NeighborSampler, RecsysStream, TokenStream
from repro.data.synthetic_graphs import densifying_graph
from repro.runtime.fault_tolerance import Heartbeat, StragglerMonitor


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    mgr.save(7, tree, blocking=True)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = mgr.restore(like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.latest_step() == 7


def test_checkpoint_partial_write_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.ones((2,))}
    mgr.save(1, tree, blocking=True)
    # simulate a crash mid-save: step dir without COMMITTED marker
    os.makedirs(tmp_path / "step_00000002")
    np.save(tmp_path / "step_00000002" / "a.npy", np.zeros(2))
    # and one killed between tmp-write and the commit rename
    os.makedirs(tmp_path / "step_00000003.tmp")
    np.save(tmp_path / "step_00000003.tmp" / "a.npy", np.zeros(2))
    with open(tmp_path / "step_00000003.tmp" / "COMMITTED", "w") as f:
        f.write("ok")
    assert mgr.latest_step() == 1          # both invisible
    out = mgr.restore({"a": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(2))


def test_checkpoint_gc_keeps_last(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"a": jnp.full((1,), float(s))}, blocking=True)
    assert mgr.committed_steps() == [3, 4]


def test_checkpoint_capture_hook(tmp_path):
    """The capture hook runs synchronously into the tmp dir; its side
    files travel with the commit rename and its return value lands in the
    manifest's ``extra`` field (DESIGN.md §15)."""
    mgr = CheckpointManager(str(tmp_path))
    seen = {}

    def capture(tmp_dir):
        seen["tmp"] = tmp_dir
        os.makedirs(os.path.join(tmp_dir, "side"))
        with open(os.path.join(tmp_dir, "side", "blob.json"), "w") as f:
            json.dump([1, 2, 3], f)
        return {"kind": "test", "n": 3}

    mgr.save(5, {"a": jnp.zeros((2,))}, blocking=True, capture=capture)
    assert seen["tmp"].endswith(".tmp")    # captured before the rename
    manifest = mgr.read_manifest(5)
    assert manifest["extra"] == {"kind": "test", "n": 3}
    with open(os.path.join(mgr.path(5), "side", "blob.json")) as f:
        assert json.load(f) == [1, 2, 3]


def test_straggler_monitor():
    m = StragglerMonitor(threshold=2.0, warmup_steps=2)
    for i in range(5):
        assert not m.record(i, 1.0)
    assert m.record(5, 3.0)            # 3x the EMA → flagged
    assert not m.record(6, 1.1)
    assert len(m.events) == 1


def test_heartbeat(tmp_path):
    path = str(tmp_path / "hb")
    hb = Heartbeat(path)
    hb.beat(3)
    assert not Heartbeat.is_stale(path, timeout=60)
    assert Heartbeat.is_stale(str(tmp_path / "missing"), timeout=60)


def test_data_determinism():
    s1 = TokenStream(1000, 8, 64, seed=1).batch_at(17)
    s2 = TokenStream(1000, 8, 64, seed=1).batch_at(17)
    np.testing.assert_array_equal(s1["tokens"], s2["tokens"])
    r1 = RecsysStream(8, 4, 100, 16, seed=2).batch_at(3)
    r2 = RecsysStream(8, 4, 100, 16, seed=2).batch_at(3)
    np.testing.assert_array_equal(r1["sparse_ids"], r2["sparse_ids"])
    # shards draw disjoint streams
    a = TokenStream(1000, 8, 64, seed=1, shard=0, num_shards=2).batch_at(0)
    b = TokenStream(1000, 8, 64, seed=1, shard=1, num_shards=2).batch_at(0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_neighbor_sampler_shapes_and_edges():
    g = densifying_graph(300, 1200, seed=0)
    s = NeighborSampler(g, batch_nodes=16, fanout=(4, 3), d_feat=8,
                        d_out=2, seed=0)
    out = s.sample(0)
    assert out.features.shape == (s.n_pad, 8)
    assert out.edge_src.shape == (s.e_pad,)
    # every edge child slot is within bounds; parents precede children
    assert out.edge_src.max() < s.n_pad
    assert out.edge_dst.max() < s.n_pad
    assert (out.edge_dst < out.edge_src).all() or True  # parents earlier
    # deterministic
    out2 = s.sample(0)
    np.testing.assert_array_equal(out.edge_src, out2.edge_src)
