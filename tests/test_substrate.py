"""Substrate: checkpoint/restart, fault tolerance, gradient compression,
data determinism, elastic remesh."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import NeighborSampler, RecsysStream, TokenStream
from repro.data.synthetic_graphs import densifying_graph
from repro.launch.train import train
from repro.optim.compress import compressed_psum, init_error_state
from repro.runtime.fault_tolerance import (Heartbeat, StragglerMonitor,
                                           elastic_remesh)


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    mgr.save(7, tree, blocking=True)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = mgr.restore(like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.latest_step() == 7


def test_checkpoint_partial_write_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.ones((2,))}
    mgr.save(1, tree, blocking=True)
    # simulate a crash mid-save: step dir without COMMITTED marker
    os.makedirs(tmp_path / "step_00000002")
    np.save(tmp_path / "step_00000002" / "a.npy", np.zeros(2))
    assert mgr.latest_step() == 1          # uncommitted step invisible
    out = mgr.restore({"a": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(2))


def test_checkpoint_gc_keeps_last(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"a": jnp.full((1,), float(s))}, blocking=True)
    assert mgr.committed_steps() == [3, 4]


def test_crash_restart_matches_uninterrupted(tmp_path):
    """The paper-grade fault-tolerance drill: fail at step 12, restart, and
    the final losses match an uninterrupted run exactly (deterministic
    pipeline + committed state)."""
    ck1 = str(tmp_path / "a")
    _, full = train("granite-moe-1b-a400m", steps=20, batch=4, seq=32,
                    seed=3, checkpoint_dir=ck1, checkpoint_every=5,
                    log_every=0)

    ck2 = str(tmp_path / "b")
    with pytest.raises(SystemExit):
        train("granite-moe-1b-a400m", steps=20, batch=4, seq=32, seed=3,
              checkpoint_dir=ck2, checkpoint_every=5, fail_at_step=12,
              log_every=0)
    _, resumed = train("granite-moe-1b-a400m", steps=20, batch=4, seq=32,
                       seed=3, checkpoint_dir=ck2, checkpoint_every=5,
                       resume=True, log_every=0)
    # resumed run restarts from step 10 (last commit before the crash)
    np.testing.assert_allclose(resumed, full[10:], rtol=1e-4, atol=1e-5)


def test_straggler_monitor():
    m = StragglerMonitor(threshold=2.0, warmup_steps=2)
    for i in range(5):
        assert not m.record(i, 1.0)
    assert m.record(5, 3.0)            # 3x the EMA → flagged
    assert not m.record(6, 1.1)
    assert len(m.events) == 1


def test_heartbeat(tmp_path):
    path = str(tmp_path / "hb")
    hb = Heartbeat(path)
    hb.beat(3)
    assert not Heartbeat.is_stale(path, timeout=60)
    assert Heartbeat.is_stale(str(tmp_path / "missing"), timeout=60)


def test_elastic_remesh(tmp_path):
    """Checkpoint written under one sharding restores under another."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree, blocking=True)
    new_shardings = {"w": NamedSharding(mesh, P("data", None))}
    out = elastic_remesh(mgr, tree, new_shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding == new_shardings["w"]


def test_compressed_psum_error_feedback():
    """int8 EF compression: single-step error is bounded; accumulated error
    feedback keeps the long-run mean unbiased."""
    from jax.sharding import Mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("dp",))
    grads = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(32, 32)).astype(np.float32))}
    err = init_error_state(grads)

    from repro.distributed import shard_map_compat

    @jax.jit
    def step(g, e):
        return shard_map_compat(
            lambda g_, e_: compressed_psum(g_, e_, "dp"),
            mesh=mesh, in_specs=(jax.sharding.PartitionSpec(),) * 2,
            out_specs=(jax.sharding.PartitionSpec(),) * 2,
        )(g, e)

    total = jnp.zeros_like(grads["w"])
    for _ in range(50):
        out, err = step(grads, err)
        total = total + out["w"]
    mean = total / 50
    # long-run mean converges to the true gradient (error feedback)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(grads["w"]),
                               atol=2e-3)


def test_data_determinism():
    s1 = TokenStream(1000, 8, 64, seed=1).batch_at(17)
    s2 = TokenStream(1000, 8, 64, seed=1).batch_at(17)
    np.testing.assert_array_equal(s1["tokens"], s2["tokens"])
    r1 = RecsysStream(8, 4, 100, 16, seed=2).batch_at(3)
    r2 = RecsysStream(8, 4, 100, 16, seed=2).batch_at(3)
    np.testing.assert_array_equal(r1["sparse_ids"], r2["sparse_ids"])
    # shards draw disjoint streams
    a = TokenStream(1000, 8, 64, seed=1, shard=0, num_shards=2).batch_at(0)
    b = TokenStream(1000, 8, 64, seed=1, shard=1, num_shards=2).batch_at(0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_neighbor_sampler_shapes_and_edges():
    g = densifying_graph(300, 1200, seed=0)
    s = NeighborSampler(g, batch_nodes=16, fanout=(4, 3), d_feat=8,
                        d_out=2, seed=0)
    out = s.sample(0)
    assert out.features.shape == (s.n_pad, 8)
    assert out.edge_src.shape == (s.e_pad,)
    # every edge child slot is within bounds; parents precede children
    assert out.edge_src.max() < s.n_pad
    assert out.edge_dst.max() < s.n_pad
    assert (out.edge_dst < out.edge_src).all() or True  # parents earlier
    # deterministic
    out2 = s.sample(0)
    np.testing.assert_array_equal(out.edge_src, out2.edge_src)
