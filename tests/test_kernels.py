"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,n,w", [(1, 16, 1), (13, 100, 7), (32, 257, 4),
                                   (8, 128, 32)])
@pytest.mark.parametrize("block_b,block_n", [(8, 128), (4, 64)])
def test_frontier_expand(b, n, w, block_b, block_n):
    rng = np.random.default_rng(b * n + w)
    p = jnp.asarray(rng.integers(0, 2 ** 32, (b, w), dtype=np.uint32))
    ext = jnp.asarray(rng.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    out = ops.frontier_expand(p, ext, block_b=block_b, block_n=block_n)
    want = ref.frontier_expand_ref(p, ext)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("e,n,d", [(64, 16, 8), (300, 50, 16), (1024, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_matmul(e, n, d, dtype):
    k = jax.random.PRNGKey(e + n)
    msg = jax.random.normal(k, (e, d), dtype)
    dst = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    out = ops.segment_matmul(msg, dst, num_nodes=n, block_n=32, block_e=128)
    want = ref.segment_matmul_ref(msg, dst, n)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("f,v,d,b", [(5, 37, 8, 9), (40, 1000, 32, 16),
                                     (1, 8, 128, 3)])
def test_embedding_bag(f, v, d, b):
    k = jax.random.PRNGKey(f * v)
    table = jax.random.normal(k, (f, v, d))
    ids = jax.random.randint(jax.random.PRNGKey(2), (b, f), 0, v)
    out = ops.embedding_bag(table, ids)
    want = ref.embedding_bag_ref(table, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("h,s,d", [(2, 128, 32), (4, 256, 64), (1, 512, 16)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(h, s, d, causal, dtype):
    k = jax.random.PRNGKey(h * s)
    q = jax.random.normal(k, (h, s, d), dtype)
    kk = jax.random.normal(jax.random.PRNGKey(1), (h, s, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (h, s, d), dtype)
    out = ops.flash_attention(q, kk, v, causal=causal, block_q=64,
                              block_k=64)
    want = ref.flash_attention_ref(q, kk, v, causal=causal)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


def test_flash_vjp_matches_dense_reference():
    """The model-side flash custom-VJP (models/flash.py): fwd+grad parity."""
    from repro.models.flash import flash_attention as model_flash
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (2, 64, 4, 16))
    kk = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 16))

    def dense(q, kk, v):
        g = q.shape[2] // kk.shape[2]
        kr = jnp.repeat(kk, g, axis=2)
        vr = jnp.repeat(v, g, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / 4.0
        mask = jnp.tril(jnp.ones((64, 64), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
        return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vr)

    f = lambda *a: model_flash(*a, causal=True, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(f(q, kk, v)),
                               np.asarray(dense(q, kk, v)),
                               rtol=2e-2, atol=2e-2)
    gf = jax.grad(lambda *a: jnp.sum(jnp.sin(f(*a))), (0, 1, 2))(q, kk, v)
    gd = jax.grad(lambda *a: jnp.sum(jnp.sin(dense(*a))), (0, 1, 2))(q, kk, v)
    for a, b in zip(gf, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-2, atol=5e-2)
