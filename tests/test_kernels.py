"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import bitset
from repro.kernels import ops, ref, runtime


@pytest.mark.parametrize("b,n,w", [(1, 16, 1), (13, 100, 7), (32, 257, 4),
                                   (8, 128, 32)])
@pytest.mark.parametrize("block_b,block_n", [(8, 128), (4, 64)])
def test_frontier_expand(b, n, w, block_b, block_n):
    rng = np.random.default_rng(b * n + w)
    p = jnp.asarray(rng.integers(0, 2 ** 32, (b, w), dtype=np.uint32))
    ext = jnp.asarray(rng.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    out = ops.frontier_expand(p, ext, block_b=block_b, block_n=block_n)
    want = ref.frontier_expand_ref(p, ext)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ---------------------------------------------- masked-intersection kernel
# ragged on purpose: W=1, B/N not multiples of any block size
@pytest.mark.parametrize("b,n,w", [(1, 16, 1), (5, 257, 1), (13, 100, 7),
                                   (32, 300, 4), (7, 1, 2)])
@pytest.mark.parametrize("block_b,block_n", [(8, 128), (3, 37)])
@pytest.mark.parametrize("with_mask", [False, True])
def test_masked_intersect_matches_reference(b, n, w, block_b, block_n,
                                            with_mask):
    rng = np.random.default_rng(b * n * w + block_b)
    a = jnp.asarray(rng.integers(0, 2 ** 32, (b, w), dtype=np.uint32))
    cols = jnp.asarray(rng.integers(0, 2 ** 32, (n, w), dtype=np.uint32))
    mask = jnp.asarray(
        rng.integers(0, 2 ** 32, (b, w), dtype=np.uint32)) if with_mask \
        else None
    out = ops.masked_intersect(a, cols, mask, block_b=block_b,
                               block_n=block_n)
    want = ref.masked_intersect_ref(a, cols, mask)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_masked_intersect_membership_via_eye_table():
    """With one-hot columns the kernel is a batched membership probe:
    counts[r, v] = bit v of (a & mask)[r] (the iso candidate-grid case)."""
    rng = np.random.default_rng(7)
    n = 100
    a = jnp.asarray(rng.integers(0, 2 ** 32, (9, 4), dtype=np.uint32))
    mask = jnp.asarray(rng.integers(0, 2 ** 32, (9, 4), dtype=np.uint32))
    eye = jnp.asarray(bitset.eye_table(n))
    member = ops.masked_intersect(a, eye, mask) > 0
    want = np.asarray(bitset.to_bool(a & mask, n))
    np.testing.assert_array_equal(np.asarray(member), want)


def test_frontier_expand_is_maskless_specialization():
    rng = np.random.default_rng(11)
    p = jnp.asarray(rng.integers(0, 2 ** 32, (6, 3), dtype=np.uint32))
    ext = jnp.asarray(rng.integers(0, 2 ** 32, (40, 3), dtype=np.uint32))
    np.testing.assert_array_equal(
        np.asarray(ops.frontier_expand(p, ext)),
        np.asarray(ops.masked_intersect(p, ext)))


# ------------------------------------------------ interpret auto-detection
def test_interpret_autodetect(monkeypatch):
    """interpret=None must lower for real on TPU and interpret elsewhere;
    REPRO_PALLAS_COMPILE=1 forces real lowering (the old hardcoded
    interpret=True silently interpreted on TPU)."""
    monkeypatch.delenv("REPRO_PALLAS_COMPILE", raising=False)
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert runtime.default_interpret() is True
    assert runtime.resolve_interpret(None) is True
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert runtime.default_interpret() is False
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    monkeypatch.setenv("REPRO_PALLAS_COMPILE", "1")
    assert runtime.default_interpret() is False
    # explicit values always win over detection
    assert runtime.resolve_interpret(True) is True
    assert runtime.resolve_interpret(False) is False


@pytest.mark.parametrize("interpret", [True, False])
def test_masked_intersect_both_execution_paths(interpret):
    """Parity in both execution modes; the compiled path runs on TPU only
    (skipped elsewhere — CPU has no Pallas TPU lowering)."""
    if not interpret and jax.default_backend() != "tpu":
        pytest.skip("compiled Pallas path requires a TPU backend")
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(0, 2 ** 32, (13, 4), dtype=np.uint32))
    cols = jnp.asarray(rng.integers(0, 2 ** 32, (130, 4), dtype=np.uint32))
    mask = jnp.asarray(rng.integers(0, 2 ** 32, (13, 4), dtype=np.uint32))
    out = ops.masked_intersect(a, cols, mask, interpret=interpret)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.masked_intersect_ref(a, cols, mask)))


# ------------------------------------------- workload kernel-path parity
def _iso_run(g, index, use_pallas, cand_path="batched"):
    from repro.core.engine import Engine, EngineConfig
    from repro.core.iso import make_iso_computation
    comp = make_iso_computation(
        g, [(0, 1), (1, 2), (2, 3)], [0, 1, 0, 2], index,
        use_pallas=use_pallas, cand_path=cand_path)
    res = Engine(comp, EngineConfig(k=3, batch=32, pool_capacity=4096,
                                    max_steps=20000)).run()
    return (np.asarray(res.result_keys).tolist(),
            np.asarray(res.result_states).tolist(), res.candidates)


def test_iso_topk_identical_with_and_without_kernel():
    """Byte-identical top-k (keys AND states) across the per-state loop,
    batched-jnp, and Pallas candidate-generation paths."""
    from repro.core.iso import build_iso_index
    from repro.data.synthetic_graphs import labeled_graph
    g = labeled_graph(n=90, m=300, n_labels=3, seed=4)
    index = build_iso_index(g, max_hops=3)
    per_state = _iso_run(g, index, use_pallas=False, cand_path="map")
    vmapped = _iso_run(g, index, use_pallas=False, cand_path="vmap")
    batched = _iso_run(g, index, use_pallas=False)
    kernel = _iso_run(g, index, use_pallas=True)
    assert per_state == vmapped == batched == kernel


def test_weighted_clique_rejects_kernel_path():
    """weighted-clique needs a weighted-popcount kernel variant, so
    use_pallas must be rejected at validation, not silently ignored."""
    from repro.data.synthetic_graphs import planted_clique_graph
    from repro.service.api import (DiscoveryRequest, GraphRegistry,
                                   ValidationError)
    reg = GraphRegistry()
    reg.register("g", planted_clique_graph(30, 100, 5, seed=0))
    req = DiscoveryRequest(graph="g", workload="weighted-clique",
                           weights=tuple([1] * 30), use_pallas=True)
    with pytest.raises(ValidationError, match="weighted-clique"):
        req.validate(reg)
    # and without the knob it still validates fine
    DiscoveryRequest(graph="g", workload="weighted-clique",
                     weights=tuple([1] * 30)).validate(reg)


def test_pattern_topk_identical_with_and_without_kernel():
    """Mining with kernel edge probes returns the identical pattern list,
    supports, and candidate count as the numpy reference path."""
    from repro.core.aggregate import topk_frequent_patterns
    from repro.data.synthetic_graphs import labeled_graph
    g = labeled_graph(n=60, m=180, n_labels=3, seed=9)
    a = topk_frequent_patterns(g, m_edges=3, k=3)
    b = topk_frequent_patterns(g, m_edges=3, k=3, use_pallas=True)
    assert a.patterns == b.patterns
    assert (a.candidates, a.groups_expanded, a.groups_pruned) == \
        (b.candidates, b.groups_expanded, b.groups_pruned)


@pytest.mark.parametrize("e,n,d", [(64, 16, 8), (300, 50, 16), (1024, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_matmul(e, n, d, dtype):
    k = jax.random.PRNGKey(e + n)
    msg = jax.random.normal(k, (e, d), dtype)
    dst = jax.random.randint(jax.random.PRNGKey(1), (e,), 0, n)
    out = ops.segment_matmul(msg, dst, num_nodes=n, block_n=32, block_e=128)
    want = ref.segment_matmul_ref(msg, dst, n)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("f,v,d,b", [(5, 37, 8, 9), (40, 1000, 32, 16),
                                     (1, 8, 128, 3)])
def test_embedding_bag(f, v, d, b):
    k = jax.random.PRNGKey(f * v)
    table = jax.random.normal(k, (f, v, d))
    ids = jax.random.randint(jax.random.PRNGKey(2), (b, f), 0, v)
    out = ops.embedding_bag(table, ids)
    want = ref.embedding_bag_ref(table, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("h,s,d", [(2, 128, 32), (4, 256, 64), (1, 512, 16)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(h, s, d, causal, dtype):
    k = jax.random.PRNGKey(h * s)
    q = jax.random.normal(k, (h, s, d), dtype)
    kk = jax.random.normal(jax.random.PRNGKey(1), (h, s, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (h, s, d), dtype)
    out = ops.flash_attention(q, kk, v, causal=causal, block_q=64,
                              block_k=64)
    want = ref.flash_attention_ref(q, kk, v, causal=causal)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=tol, atol=tol)
