"""Staleness-tolerant bound exchange (DESIGN.md §14) property suite.

`sync_every = K` lets the sharded fused loop run K shard-local inner steps
between §4 `bound_sync` all-gathers, pruning in the interim against
max(last-exchanged global bound, fresh local k-th best).  Both quantities
are lower bounds on the fresh global k-th best (result sets only improve,
and a shard's local results are a subset of the union), so the interim
threshold is only ever *looser* than the fresh one — pruning stays sound
and complete runs are byte-identical for any K.  This file carries that
argument as executable properties:

* fuzzed parity matrix: random graphs × workload × shards × K ×
  steps_per_sync, byte-identical to the K=1 single-device run;
* monotonicity: the bound each shard actually pruned with never exceeds
  the fresh global bound at the same inner step (recorded via the
  `record_bound_trace` hook), and is exactly the fresh bound at K=1;
* collective-count regression: `EngineResult.syncs` == ceil(steps / K),
  so a refactor cannot silently reintroduce per-step all-gathers;
* budget truncation lands on the same step count for any (K, T) at a
  fixed shard count, mirroring the PR 5 guarantees;
* cache keys: `sync_every` is excluded from the service result-cache key
  but included in the engine-reuse key — both directions asserted.

Shard tiers activate on the visible device count (`_require_devices`), so
the 2-shard tier runs wherever 2 host devices are forced (the tier-1 CI
job) and the 8-shard tier in the CI ``distributed`` job; one subprocess
test keeps a compact 2-shard staleness matrix alive even in a plain
single-device run.  The matrix is fuzzed with seeded numpy RNG so it
never depends on hypothesis; an extra hypothesis-driven sweep activates
when the library is installed (CI).
"""
import dataclasses
import math
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.core.iso import build_iso_index, make_iso_computation
from repro.core.weighted_clique import make_weighted_clique_computation
from repro.data.synthetic_graphs import densifying_graph, labeled_graph
from repro.distributed import ShardedEngine
from repro.service import (DiscoveryRequest, DiscoveryService,
                           ValidationError)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # tier-1 containers ship without hypothesis
    HAVE_HYPOTHESIS = False


def _require_devices(n: int) -> None:
    if len(jax.devices()) < n:
        pytest.skip(f"needs >= {n} devices (force host devices with "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count={n})")


def _tiers():
    return tuple(s for s in (1, 2, 8) if s <= len(jax.devices()))


def _assert_parity(ref, res, ctx=""):
    assert np.array_equal(ref.result_keys, res.result_keys), \
        (ctx, ref.result_keys, res.result_keys)
    assert np.array_equal(ref.result_states, res.result_states), ctx


def _make_workload(kind: str, seed: int):
    """Seeded random (graph, computation) pair for one workload family."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 72))
    m = int(rng.integers(2 * n, 5 * n))
    if kind == "clique":
        return make_clique_computation(densifying_graph(n, m, seed=seed))
    if kind == "weighted-clique":
        g = densifying_graph(n, m, seed=seed)
        return make_weighted_clique_computation(
            g, rng.integers(1, 20, g.n))
    assert kind == "iso"
    gl = labeled_graph(n=n, m=m, n_labels=3, seed=seed)
    return make_iso_computation(gl, [(0, 1), (1, 2), (0, 2)], [1, 1, 1],
                                build_iso_index(gl, max_hops=2))


_CFG = EngineConfig(k=3, batch=8, pool_capacity=64, max_steps=50_000)


# ----------------------------------------------------- fuzzed parity matrix
@pytest.mark.parametrize("kind,seed", [
    ("clique", 11), ("clique", 12), ("iso", 13), ("weighted-clique", 14)])
def test_stale_parity_fuzzed(kind, seed):
    """Complete runs are byte-identical to the K=1 single-device run for
    every (shards, K, steps_per_sync) combination the device count
    allows — the DESIGN.md §14 soundness claim, end to end."""
    comp = _make_workload(kind, seed)
    ref = Engine(comp, _CFG).run()
    for shards in _tiers():
        for K in (1, 2, 4, 8):
            for T in (1, 4):
                res = ShardedEngine(comp, dataclasses.replace(
                    _CFG, shards=shards, sync_every=K,
                    steps_per_sync=T)).run()
                _assert_parity(ref, res, (kind, shards, K, T))


# --------------------------------------------- monotonicity: stale <= fresh
@pytest.mark.parametrize("shards", [1, 2, 8])
@pytest.mark.parametrize("K", [1, 4])
def test_stale_bound_never_exceeds_fresh(shards, K):
    """The bound a shard actually prunes with is (a) never above the fresh
    global bound a per-step exchange would have produced at the same
    inner step — stale means *looser*, never tighter — and (b) exactly
    the fresh bound at K=1.  Fresh bounds are monotone nondecreasing,
    which is what makes (a) sufficient for soundness."""
    _require_devices(shards)
    comp = _make_workload("clique", 21)
    res = ShardedEngine(comp, dataclasses.replace(
        _CFG, shards=shards, sync_every=K, steps_per_sync=4,
        record_bound_trace=True)).run()
    used = np.asarray(res.per_shard["bound_used"])
    fresh = np.asarray(res.per_shard["bound_fresh"])
    assert used.shape == (shards, res.steps)
    assert fresh.shape == (shards, res.steps)
    assert np.all(used <= fresh)
    assert np.all(np.diff(fresh, axis=1) >= 0)   # fresh bound is monotone
    if K == 1:
        np.testing.assert_array_equal(used, fresh)
    else:
        # at least one exchange boundary actually ran with a fresh bound
        assert np.any(used == fresh)


# ------------------------------------------------ collective-count contract
@pytest.mark.parametrize("shards", [1, 2, 8])
def test_syncs_count_is_ceil_steps_over_k(shards):
    """syncs == ceil(steps / K) exactly: the observable proof that the
    fleet exchanges bounds every K-th inner step and not once per step.
    Guards against a refactor quietly moving bound_sync back into the
    per-step path."""
    _require_devices(shards)
    comp = _make_workload("clique", 31)
    for K in (1, 2, 4, 8):
        for T in (1, 4):
            res = ShardedEngine(comp, dataclasses.replace(
                _CFG, shards=shards, sync_every=K,
                steps_per_sync=T)).run()
            assert res.syncs == math.ceil(res.steps / K), \
                (shards, K, T, res.steps, res.syncs)
            assert res.host_syncs <= res.syncs


def test_single_device_engine_has_no_collectives():
    """The plain Engine never exchanges bounds: syncs stays 0 (host
    round-trips are reported separately as host_syncs)."""
    comp = _make_workload("clique", 31)
    for T in (1, 4):
        res = Engine(comp, dataclasses.replace(
            _CFG, steps_per_sync=T)).run()
        assert res.syncs == 0
        assert res.host_syncs > 0


# ------------------------------------------------- budget truncation
@pytest.mark.parametrize("shards", [1, 2, 8])
def test_budget_truncates_identically_across_k(shards):
    """max_steps lands on exactly the same step count for any (K, T) at a
    fixed shard count, and the truncated result arrays are identical —
    sync_every never changes what a budgeted run returns."""
    _require_devices(shards)
    comp = _make_workload("clique", 41)
    full = ShardedEngine(comp, dataclasses.replace(
        _CFG, shards=shards)).run()
    budget = max(2, full.steps // 2)
    ref = None
    for K in (1, 2, 4):
        for T in (1, 4):
            res = ShardedEngine(comp, dataclasses.replace(
                _CFG, shards=shards, sync_every=K, steps_per_sync=T,
                max_steps=budget)).run()
            assert res.steps == budget, (K, T, res.steps, budget)
            if ref is None:
                ref = res
            else:
                _assert_parity(ref, res, (shards, K, T))


def test_service_step_budget_with_sync_every():
    """step_budget through the service layer truncates at the same step
    count for any K, and the syncs/host_syncs accounting reaches the
    response stats."""
    g = densifying_graph(64, 256, seed=5)
    svc = DiscoveryService()
    svc.register_graph("g", g)
    for K in (1, 4):
        resp = svc.query(DiscoveryRequest(
            graph="g", workload="clique", k=3, batch=8, pool_capacity=64,
            step_budget=6, sync_every=K, steps_per_sync=4,
            use_cache=False))
        assert resp.status == "ok", resp.error
        assert resp.terminated == "step_budget"
        assert resp.stats["steps"] == 6, (K, resp.stats["steps"])
        assert "syncs" in resp.stats and "host_syncs" in resp.stats
        assert resp.stats["syncs"] == 0   # single-device: no collectives


# --------------------------------------------------------------- cache keys
def test_sync_every_excluded_from_result_cache_key():
    """Direction 1: requests differing only in sync_every share one
    result-cache entry (complete runs are byte-identical, so caching
    across K is sound and saves the recompute)."""
    r1 = DiscoveryRequest(graph="g", workload="clique", k=3)
    r2 = dataclasses.replace(r1, sync_every=4)
    assert r1.canonical_spec() == r2.canonical_spec()
    svc = DiscoveryService()
    svc.register_graph("g", densifying_graph(48, 160, seed=3))
    first = svc.query(DiscoveryRequest(graph="g", workload="clique", k=3))
    hit = svc.query(DiscoveryRequest(graph="g", workload="clique", k=3,
                                     sync_every=8))
    assert first.status == "ok" and hit.status == "ok"
    assert not first.cached and hit.cached
    assert first.result_keys == hit.result_keys


def test_sync_every_included_in_engine_reuse_key():
    """Direction 2: sync_every changes the compiled fused program, so
    requests differing only in K must NOT share a compiled engine."""
    svc = DiscoveryService()
    svc.register_graph("g", densifying_graph(48, 160, seed=3))
    base = dict(graph="g", workload="clique", k=3, use_cache=False)
    svc.query(DiscoveryRequest(**base))
    assert len(svc._engines) == 1
    svc.query(DiscoveryRequest(**base))            # same K: engine reused
    assert len(svc._engines) == 1
    svc.query(DiscoveryRequest(**base, sync_every=4))
    assert len(svc._engines) == 2                  # new K: new engine
    svc.query(DiscoveryRequest(**base, sync_every=4))
    assert len(svc._engines) == 2


# ------------------------------------------------------- request validation
def test_sync_every_validated_and_coerced():
    from repro.service.api import GraphRegistry
    reg = GraphRegistry()
    reg.register("g", densifying_graph(32, 64, seed=0))
    with pytest.raises(ValidationError, match="sync_every"):
        DiscoveryRequest(graph="g", workload="clique", k=1,
                         sync_every=0).validate(reg)
    req = DiscoveryRequest.from_dict(
        dict(graph="g", workload="clique", k=1, sync_every="4"))
    assert req.sync_every == 4
    with pytest.raises(ValueError):
        ShardedEngine(make_clique_computation(densifying_graph(
            32, 64, seed=0)), EngineConfig(k=1, sync_every=0))


# ------------------------------------- subprocess tier: 2 shards, 1 device
_STALE_PROG = """
    import dataclasses, math
    import numpy as np
    from repro.core.clique import make_clique_computation
    from repro.core.engine import Engine, EngineConfig
    from repro.core.iso import build_iso_index, make_iso_computation
    from repro.core.weighted_clique import make_weighted_clique_computation
    from repro.data.synthetic_graphs import densifying_graph, labeled_graph
    from repro.distributed import ShardedEngine

    cfg = EngineConfig(k=3, batch=8, pool_capacity=64, max_steps=50_000)
    rng = np.random.default_rng(51)
    g = densifying_graph(56, 220, seed=51)
    gl = labeled_graph(n=56, m=190, n_labels=3, seed=52)
    comps = [
        ("clique", make_clique_computation(g)),
        ("weighted", make_weighted_clique_computation(
            g, rng.integers(1, 20, g.n))),
        ("iso", make_iso_computation(
            gl, [(0, 1), (1, 2), (0, 2)], [1, 1, 1],
            build_iso_index(gl, max_hops=2))),
    ]
    for name, comp in comps:
        ref = Engine(comp, cfg).run()
        for K in (2, 8):
            res = ShardedEngine(comp, dataclasses.replace(
                cfg, shards=2, sync_every=K, steps_per_sync=4)).run()
            assert np.array_equal(ref.result_keys, res.result_keys), \\
                (name, K)
            assert np.array_equal(ref.result_states, res.result_states), \\
                (name, K)
            assert res.syncs == math.ceil(res.steps / K), (name, K)
        print(f"STALE-2SHARD-OK {name}", flush=True)
"""


def test_stale_parity_two_shards_subprocess():
    """Keeps the 2-shard staleness matrix exercised even when the calling
    interpreter has a single device (plain tier-1): re-runs a compact
    workload × K parity + sync-count program under 2 forced host
    devices."""
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=2",
        JAX_PLATFORMS="cpu",
        PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_STALE_PROG)],
        capture_output=True, text=True, timeout=420, env=env)
    for name in ("clique", "weighted", "iso"):
        assert f"STALE-2SHARD-OK {name}" in res.stdout, \
            (res.stdout, res.stderr[-3000:])


# ------------------------------------------------ hypothesis sweep (CI only)
if HAVE_HYPOTHESIS:
    settings.register_profile("stale", max_examples=10, deadline=None)
    settings.load_profile("stale")

    @given(seed=st.integers(0, 2 ** 16), K=st.sampled_from([2, 3, 5, 8]),
           T=st.sampled_from([1, 3, 4]))
    def test_stale_parity_hypothesis(seed, K, T):
        """Hypothesis-driven corner of the matrix: arbitrary seeds and
        non-power-of-two cadences on whatever shard tiers exist."""
        comp = _make_workload("clique", seed)
        ref = Engine(comp, _CFG).run()
        for shards in _tiers():
            res = ShardedEngine(comp, dataclasses.replace(
                _CFG, shards=shards, sync_every=K,
                steps_per_sync=T)).run()
            _assert_parity(ref, res, (seed, shards, K, T))
