"""Clique discovery: engine vs exact brute force, pruning efficacy, spill."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.api import NEG
from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig, merge_topk
from repro.core.exhaustive import (ArabesqueStyleClique,
                                   brute_force_max_clique,
                                   nuri_np_clique_candidates)
from repro.data.synthetic_graphs import (densifying_graph,
                                         planted_clique_graph)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("n,m,k_clique", [(60, 200, 5), (120, 400, 7)])
def test_max_clique_matches_bruteforce(seed, n, m, k_clique):
    g = planted_clique_graph(n=n, m=m, clique_size=k_clique, seed=seed)
    size_bf, _ = brute_force_max_clique(g)
    comp = make_clique_computation(g)
    eng = Engine(comp, EngineConfig(k=1, batch=32, pool_capacity=2048,
                                    max_steps=20000))
    res = eng.run()
    assert res.result_keys[0] == size_bf
    # returned subgraph is actually a clique of that size
    members = comp.describe(res.result_states[0])
    assert len(members) == size_bf
    for i, u in enumerate(members):
        for v in members[i + 1:]:
            assert g.has_edge(u, v)


def test_topk_cliques():
    g = densifying_graph(80, 300, seed=4)
    comp = make_clique_computation(g)
    res = Engine(comp, EngineConfig(k=5, batch=32, pool_capacity=4096,
                                    max_steps=20000)).run()
    size_bf, _ = brute_force_max_clique(g)
    keys = list(res.result_keys)
    assert keys[0] == size_bf
    assert keys == sorted(keys, reverse=True)
    # every result is a valid clique
    for i in range(5):
        members = comp.describe(res.result_states[i])
        assert len(members) == keys[i]
        for a, u in enumerate(members):
            for v in members[a + 1:]:
                assert g.has_edge(u, v)


def test_pruning_beats_nuri_np_and_exhaustive():
    """The paper's headline: prioritization+pruning examines far fewer
    candidates than Nuri-NP, which beats Arabesque-style exhaustive."""
    g = densifying_graph(100, 600, seed=7)
    comp = make_clique_computation(g)
    res = Engine(comp, EngineConfig(k=1, batch=32, pool_capacity=8192,
                                    max_steps=50000)).run()
    np_res = nuri_np_clique_candidates(g)
    abq = ArabesqueStyleClique(g).run()
    assert np_res["completed"]
    assert res.result_keys[0] == np_res["max_clique_size"]
    assert res.candidates < np_res["candidates"]
    if abq["completed"]:
        assert np_res["candidates"] <= abq["candidates"]


@pytest.mark.parametrize("spill", ["host", "disk"])
def test_spill_path_identical_results(tmp_path, spill):
    """A pool far too small forces VPQ spill; results must be unchanged."""
    g = densifying_graph(90, 500, seed=3)
    comp = make_clique_computation(g)
    big = Engine(comp, EngineConfig(k=3, batch=16, pool_capacity=8192,
                                    max_steps=50000)).run()
    small = Engine(comp, EngineConfig(
        k=3, batch=16, pool_capacity=96, max_steps=50000, spill=spill,
        spill_dir=str(tmp_path) if spill == "disk" else None)).run()
    assert list(small.result_keys) == list(big.result_keys)
    assert small.spilled > 0


def test_merge_topk_canonical_and_deduped():
    """The result merge collapses duplicate (state, key) pairs — a deferred
    parent contributes its result key again on re-dequeue — and breaks key
    ties by state content, insertion-order independently."""
    states = jnp.asarray([[1, 2], [3, 4], [1, 2], [5, 6], [7, 7]], jnp.int32)
    keys = jnp.asarray([10, 9, 10, 8, NEG], jnp.int32)
    s, k = merge_topk(states, keys, 3)
    assert list(k) == [10, 9, 8]          # duplicate [1,2] holds ONE slot
    assert np.asarray(s).tolist() == [[1, 2], [3, 4], [5, 6]]
    # permutation invariance (the sharded-parity prerequisite)
    perm = [3, 2, 4, 0, 1]
    s2, k2 = merge_topk(states[jnp.asarray(perm)], keys[jnp.asarray(perm)], 3)
    assert np.array_equal(s, s2) and np.array_equal(k, k2)
    # key ties break by state words ascending; NEG slots come back zeroed
    s3, k3 = merge_topk(states, jnp.asarray([5, 5, 5, 5, NEG], jnp.int32), 5)
    assert np.asarray(s3).tolist() == [[1, 2], [3, 4], [5, 6], [0, 0], [0, 0]]
    assert list(k3) == [5, 5, 5, NEG, NEG]
    # a NEG-keyed copy sorted between two real-keyed copies of the same
    # state must not hide them from the dedup (key is a sort column)
    s4, k4 = merge_topk(jnp.asarray([[1, 2]] * 3, jnp.int32),
                        jnp.asarray([10, NEG, 10], jnp.int32), 3)
    assert list(k4) == [10, NEG, NEG]


def test_deferral_pressure_no_duplicate_results():
    """Dequeuing far more parents than the materialization budget M admits
    (M floors at A = n) defers parents constantly; re-dequeued parents must
    not occupy two result slots (regression: duplicate result rows
    displaced the true k-th result and over-tightened the threshold)."""
    g = densifying_graph(80, 400, seed=6)
    comp = make_clique_computation(g)
    # low deferral pressure vs heavy: B=48 parents share an M=80 budget
    ref = Engine(comp, EngineConfig(k=5, batch=4, pool_capacity=8192,
                                    max_steps=50000)).run()
    squeezed = Engine(comp, EngineConfig(k=5, batch=48, pool_capacity=8192,
                                         max_steps=50000)).run()
    assert np.array_equal(ref.result_keys, squeezed.result_keys)
    assert np.array_equal(ref.result_states, squeezed.result_states)
    rows = [tuple(r) for r in np.asarray(squeezed.result_states)]
    assert len(set(rows)) == len(rows), "duplicate result states"


def test_batch_one_matches_paper_order():
    """B=1 reproduces the paper's strict single-subgraph priority order."""
    g = planted_clique_graph(40, 80, clique_size=5, seed=9)
    comp = make_clique_computation(g)
    res = Engine(comp, EngineConfig(k=1, batch=1, pool_capacity=4096,
                                    max_steps=100000)).run()
    size_bf, _ = brute_force_max_clique(g)
    assert res.result_keys[0] == size_bf
