"""Crash-injection harness for durable discovery runs (DESIGN.md §15).

Runs one discovery workload in THIS process as directed by a JSON spec,
in one of three modes:

* ``oracle`` — uninterrupted run, no checkpointing; prints the result.
* ``crash``  — run with periodic checkpointing and a kill switch armed:
  ``kill_at_step N`` SIGKILLs the process at the first host-sync boundary
  whose step count reaches ``N``; ``kill_in_commit N`` SIGKILLs *inside*
  the checkpoint manager's commit, after the tmp dir is fully written but
  before the atomic rename — the exact window the §15 protocol claims is
  safe.  The process dies by SIGKILL; nothing is printed.
* ``resume`` — run with ``resume=True``: continue from the newest
  committed step (fresh start if the crash preceded the first commit)
  and print the result.

The parent test (``test_fault_injection.py``) asserts the resumed result
is byte-identical to the oracle's — top-k states, keys, and every
counter.  The harness is import-safe (the parent reuses its helpers) and
runs as a script in a subprocess so the SIGKILL is real::

    PYTHONPATH=src python tests/fault_harness.py --spec '<json>' --mode crash
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal
import sys


def make_workload(kind: str, seed: int):
    """Seeded random (graph, computation) pair — the same families as the
    staleness suite: clique / weighted-clique / iso."""
    import numpy as np
    from repro.core.clique import make_clique_computation
    from repro.core.iso import build_iso_index, make_iso_computation
    from repro.core.weighted_clique import make_weighted_clique_computation
    from repro.data.synthetic_graphs import densifying_graph, labeled_graph

    rng = np.random.default_rng(seed)
    n = int(rng.integers(64, 96))
    m = int(rng.integers(6 * n, 12 * n))
    if kind == "clique":
        return make_clique_computation(densifying_graph(n, m, seed=seed))
    if kind == "weighted-clique":
        g = densifying_graph(n, m, seed=seed)
        return make_weighted_clique_computation(g, rng.integers(1, 20, g.n))
    assert kind == "iso", kind
    gl = labeled_graph(n=n, m=m, n_labels=3, seed=seed)
    return make_iso_computation(gl, [(0, 1), (1, 2), (0, 2)], [1, 1, 1],
                                build_iso_index(gl, max_hops=2))


def build_engine(spec: dict, checkpointed: bool):
    """Engine (1 shard) or ShardedEngine (>1) for ``spec``; checkpointing
    knobs attach only when ``checkpointed``."""
    from repro.core.engine import Engine, EngineConfig
    from repro.distributed import ShardedEngine

    comp = make_workload(spec["kind"], spec["seed"])
    cfg = EngineConfig(
        k=spec.get("k", 3), batch=spec.get("batch", 4),
        pool_capacity=spec.get("pool_capacity", 48), max_steps=50_000,
        spill=spec.get("spill", "host"),
        spill_dir=spec.get("spill_dir"),
        shards=spec.get("shards", 1),
        steps_per_sync=spec.get("T", 1),
        sync_every=spec.get("K", 1),
        checkpoint_every=spec["checkpoint_every"] if checkpointed else 0,
        checkpoint_dir=spec["ckpt_dir"] if checkpointed else None)
    if cfg.shards > 1:
        return ShardedEngine(comp, cfg)
    return Engine(comp, dataclasses.replace(cfg, shards=1))


def result_to_json(res) -> str:
    return json.dumps({
        "result_keys": [int(x) for x in res.result_keys],
        "result_states": [[int(x) for x in row]
                          for row in res.result_states],
        "steps": res.steps, "candidates": res.candidates,
        "expanded": res.expanded, "pruned": res.pruned,
        "spilled": res.spilled, "refilled": res.refilled,
        "late_pruned": res.late_pruned, "syncs": res.syncs,
        "host_syncs": res.host_syncs,
        "rebalanced": getattr(res, "rebalanced", 0)}, sort_keys=True)


def _arm_kill_at_step(eng, n: int):
    """SIGKILL at the first host-sync boundary where ``steps >= n`` —
    mid-run, with the async writer possibly in flight."""
    inner = eng.step

    def step(st, max_inner=None):
        out = inner(st, max_inner=max_inner)
        if out.steps >= n:
            os.kill(os.getpid(), signal.SIGKILL)
        return out

    eng.step = step


def _arm_kill_in_commit(n: int):
    """SIGKILL inside the ``n``-th checkpoint commit, after the tmp dir is
    complete (leaves + manifest + COMMITTED) but before the rename — the
    window the atomic-commit protocol must survive."""
    from repro.checkpoint.manager import CheckpointManager
    count = [0]
    inner = CheckpointManager._commit

    def commit(self, tmp, final):
        count[0] += 1
        if count[0] >= n:
            os.kill(os.getpid(), signal.SIGKILL)
        return inner(self, tmp, final)

    CheckpointManager._commit = commit


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", required=True, help="JSON workload spec")
    ap.add_argument("--mode", required=True,
                    choices=("oracle", "crash", "resume"))
    args = ap.parse_args(argv)
    spec = json.loads(args.spec)

    if args.mode == "oracle":
        eng = build_engine(spec, checkpointed=False)
        res = eng.run()
    elif args.mode == "crash":
        eng = build_engine(spec, checkpointed=True)
        if spec.get("kill_in_commit"):
            _arm_kill_in_commit(int(spec["kill_in_commit"]))
        if spec.get("kill_at_step"):
            _arm_kill_at_step(eng, int(spec["kill_at_step"]))
        # spec["resume"] arms a SECOND crash cycle: continue from the
        # newest committed step, then die again further along
        eng.run(resume=bool(spec.get("resume")))
        # the kill switch should have fired; reaching here means the kill
        # point was past the end of the run — a parent-test bug
        print("crash mode survived to completion", file=sys.stderr)
        return 3
    else:
        eng = build_engine(spec, checkpointed=True)
        res = eng.run(resume=True)
    print("RESULT " + result_to_json(res), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
