"""Label-constrained discovery (DESIGN.md §12): attributed storage,
predicate validation, pushdown-vs-host-filter byte parity, cache keying,
and sharded parity.

Run by the CI ``docs`` job under ``XLA_FLAGS=
--xla_force_host_platform_device_count=8`` so the in-process sharded
variants execute on CPU-only runners (kernels auto-detect interpret mode
there — the parity contract, docs/KERNELS.md).
"""
import numpy as np
import pytest

import jax

from repro.core.aggregate import topk_frequent_patterns
from repro.core.engine import Engine, EngineConfig
from repro.core.exhaustive import brute_force_iso
from repro.core.graph import GraphStore
from repro.core.iso import build_iso_index, make_iso_computation
from repro.core.labels import LabelPredicate
from repro.data.synthetic_graphs import attributed_graph, labeled_graph

NEG = np.iinfo(np.int32).min


# ------------------------------------------------------------------- storage
def test_edge_labels_aligned_and_deduped():
    edges = np.array([[0, 1], [1, 2], [2, 3], [0, 2], [1, 0], [2, 2]])
    et = np.array([0, 1, 0, 1, 1, 0])        # dup (1,0) + self-loop dropped
    g = GraphStore.from_edges(4, edges, labels=np.array([0, 1, 1, 2]),
                              edge_labels=et)
    assert g.num_edges == 4 and g.n_edge_labels == 2
    # each directed CSR slot carries its undirected edge's type (first
    # occurrence wins on duplicates)
    want = {(0, 1): 0, (1, 2): 1, (2, 3): 0, (0, 2): 1}
    for (u, v), lab in zip(g.edge_array, g.edge_labels):
        assert want[(min(u, v), max(u, v))] == lab


def test_etype_planes_partition_adjacency():
    g = attributed_graph(n=60, m=200, n_labels=4, n_edge_labels=3, seed=2)
    planes = g.etype_adj_bits
    assert planes.shape[0] == g.n_edge_labels
    # OR over all planes is the full adjacency; planes are disjoint
    assert np.array_equal(np.bitwise_or.reduce(planes, axis=0), g.adj_bits)
    for t in range(planes.shape[0]):
        for s in range(t + 1, planes.shape[0]):
            assert not np.any(planes[t] & planes[s])


def test_fingerprint_covers_edge_labels():
    edges = np.array([[0, 1], [1, 2]])
    labels = np.array([0, 1, 0])
    g0 = GraphStore.from_edges(3, edges, labels=labels)
    g1 = GraphStore.from_edges(3, edges, labels=labels,
                               edge_labels=np.array([0, 0]))
    g2 = GraphStore.from_edges(3, edges, labels=labels,
                               edge_labels=np.array([0, 1]))
    assert len({g0.fingerprint, g1.fingerprint, g2.fingerprint}) == 3


# ----------------------------------------------------------------- predicate
def test_predicate_canonicalization_and_rejects():
    p = LabelPredicate.from_spec(
        {"vertex_any_of": [2, 1, 2], "q_any_of": [[1], [3, 1]]})
    assert p.vertex_any_of == (1, 2)
    assert p.q_any_of == ((1,), (1, 3))
    assert LabelPredicate.from_spec({}) is None
    assert LabelPredicate.from_spec(None) is None
    for bad in ({"vertex_any_of": []},
                {"vertex_any_of": [-1]},
                {"nope": [1]},
                {"vertex_any_of": "abc"},
                [1, 2]):
        with pytest.raises(ValueError):
            LabelPredicate.from_spec(bad)
    g = labeled_graph(20, 40, 3, seed=0)
    with pytest.raises(ValueError, match="out of range"):
        LabelPredicate.from_spec({"vertex_any_of": [7]}).validate(g, "iso")
    with pytest.raises(ValueError, match="edge_labels"):
        LabelPredicate.from_spec({"edge_any_of": [0]}).validate(g, "iso")
    with pytest.raises(ValueError, match="iso only"):
        LabelPredicate.from_spec({"q_any_of": [[0]]}).validate(g, "pattern")
    with pytest.raises(ValueError, match="3 classes for 2"):
        LabelPredicate.from_spec(
            {"q_any_of": [[0], [1], [2]]}).validate(g, "iso", nq=2)


# ----------------------------------------------------------- iso label parity
def _iso_keys(res):
    return [int(x) for x in res.result_keys if int(x) != NEG]


CFG = EngineConfig(k=4, batch=16, pool_capacity=2048, max_steps=50_000)


@pytest.mark.parametrize("spec", [
    {"vertex_any_of": [1, 2]},
    {"q_any_of": [[1, 2], [1], [0, 1]]},
    {"vertex_any_of": [0, 1], "q_any_of": [[1, 2], [1], [0, 1]]},
])
def test_iso_pushdown_post_parity_and_oracle(spec):
    g = labeled_graph(n=50, m=160, n_labels=3, seed=7)
    index = build_iso_index(g, max_hops=2)
    q_edges, q_labels = [(0, 1), (1, 2)], [1, 1, 1]
    pred = LabelPredicate.from_spec(spec)
    runs = {}
    for lf in ("pushdown", "post"):
        for pallas in (False, True):
            comp = make_iso_computation(
                g, q_edges, q_labels, index, predicate=pred,
                label_filter=lf, use_pallas=pallas)
            runs[(lf, pallas)] = Engine(comp, CFG).run()
    ref = runs[("pushdown", False)]
    for key, res in runs.items():
        assert np.array_equal(ref.result_keys, res.result_keys), key
        assert np.array_equal(ref.result_states, res.result_states), key
    oracle = brute_force_iso(g, q_edges, q_labels, k=CFG.k, predicate=pred)
    assert _iso_keys(ref) == [s for s, _ in oracle]


def test_iso_edge_predicate_matches_oracle():
    g = attributed_graph(n=40, m=150, n_labels=2, n_edge_labels=2, seed=9)
    q_edges, q_labels = [(0, 1), (1, 2)], [0, 1, 0]
    pred = LabelPredicate.from_spec({"edge_any_of": [0]})
    # the index must see the same predicate: restricted-adjacency hop
    # reachability, full-graph degrees (build_iso_index docstring)
    index = build_iso_index(g, max_hops=2, predicate=pred)
    res = Engine(make_iso_computation(
        g, q_edges, q_labels, index, predicate=pred), CFG).run()
    oracle = brute_force_iso(g, q_edges, q_labels, k=CFG.k, predicate=pred)
    assert _iso_keys(res) == [s for s, _ in oracle]
    # and the restriction really binds: the unconstrained run (with its
    # own unrestricted index) finds at least as much
    free = Engine(make_iso_computation(
        g, q_edges, q_labels, build_iso_index(g, max_hops=2)), CFG).run()
    assert len(_iso_keys(free)) >= len(_iso_keys(res))


def test_iso_all_cand_paths_agree_under_predicate():
    g = labeled_graph(n=40, m=120, n_labels=3, seed=3)
    index = build_iso_index(g, max_hops=2)
    pred = LabelPredicate.from_spec({"vertex_any_of": [0, 1]})
    outs = []
    for path in ("batched", "vmap", "map"):
        comp = make_iso_computation(
            g, [(0, 1), (1, 2), (0, 2)], [1, 1, 1], index,
            predicate=pred, cand_path=path)
        outs.append(Engine(comp, CFG).run())
    for res in outs[1:]:
        assert np.array_equal(outs[0].result_keys, res.result_keys)
        assert np.array_equal(outs[0].result_states, res.result_states)


# ------------------------------------------------------------- pattern parity
@pytest.mark.parametrize("pallas", [False, True])
def test_pattern_pushdown_post_parity(pallas):
    g = attributed_graph(n=70, m=260, n_labels=4, n_edge_labels=2, seed=5)
    pred = LabelPredicate.from_spec(
        {"vertex_any_of": [0, 1, 2], "edge_any_of": [0]})
    post = topk_frequent_patterns(g, m_edges=2, k=3, predicate=pred,
                                  label_filter="post", use_pallas=pallas)
    push = topk_frequent_patterns(g, m_edges=2, k=3, predicate=pred,
                                  label_filter="pushdown",
                                  use_pallas=pallas)
    assert post.patterns == push.patterns
    assert push.candidates <= post.candidates


def test_pattern_edge_predicate_equals_restricted_graph():
    """Mining with edge_any_of must equal mining the spanning subgraph
    that keeps only allowed-type edges."""
    g = attributed_graph(n=60, m=220, n_labels=3, n_edge_labels=2, seed=11)
    pred = LabelPredicate.from_spec({"edge_any_of": [1]})
    constrained = topk_frequent_patterns(g, m_edges=2, k=3, predicate=pred)
    keep = np.asarray(g.edge_labels) == 1
    sub = GraphStore.from_edges(g.n, g.edge_array[keep], labels=g.labels)
    plain = topk_frequent_patterns(sub, m_edges=2, k=3)
    assert constrained.patterns == plain.patterns


# ------------------------------------------------------------------- service
def test_service_label_cache_key_and_validation():
    from repro.service import DiscoveryRequest, DiscoveryService
    svc = DiscoveryService()
    svc.register_graph("g", labeled_graph(40, 120, 3, seed=1))
    base = dict(graph="g", workload="iso", k=2,
                q_edges=[[0, 1], [1, 2]], q_labels=[1, 1, 1])
    spec = dict(base, label_predicate={"vertex_any_of": [1, 2]})
    r1 = svc.query(DiscoveryRequest.from_dict(spec))
    assert r1.status == "ok" and not r1.cached
    # canonical predicate: order/duplicates key identically -> cache hit
    r2 = svc.query(DiscoveryRequest.from_dict(
        dict(base, label_predicate={"vertex_any_of": [2, 1, 1]})))
    assert r2.cached and r2.result_keys == r1.result_keys
    # label_filter is part of the key (truncated runs are mode-dependent)
    r3 = svc.query(DiscoveryRequest.from_dict(
        dict(spec, label_filter="post")))
    assert not r3.cached and r3.result_keys == r1.result_keys
    # unconstrained request must not collide with the constrained one
    r4 = svc.query(DiscoveryRequest.from_dict(base))
    assert not r4.cached
    # validation errors surface as error responses
    for bad in (dict(base, label_predicate={"vertex_any_of": [9]}),
                dict(base, label_predicate={"bogus": [1]}),
                dict(base, label_filter="sideways"),
                dict(base, workload="clique",
                     label_predicate={"vertex_any_of": [0]})):
        bad.setdefault("q_edges", base["q_edges"])
        resp = svc.query(DiscoveryRequest.from_dict(bad))
        assert resp.status == "error", bad


# ------------------------------------------------ in-process (CI docs job)
@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs >= 8 devices (CI docs job forces 8 host "
                           "devices)")
def test_labeled_iso_parity_sharded():
    """Labeled top-k is byte-identical across host-filter/pushdown AND
    across 1/2/8 shards — the §11 parity argument covers the label-
    constrained computations unchanged (closure-constant masks)."""
    import dataclasses
    from repro.distributed import ShardedEngine
    g = labeled_graph(n=50, m=160, n_labels=3, seed=7)
    index = build_iso_index(g, max_hops=2)
    pred = LabelPredicate.from_spec(
        {"vertex_any_of": [1, 2], "q_any_of": [[1, 2], [1], [1, 2]]})
    cfg = EngineConfig(k=4, batch=16, pool_capacity=1024, max_steps=50_000)
    ref = None
    for lf in ("pushdown", "post"):
        comp = make_iso_computation(
            g, [(0, 1), (1, 2), (0, 2)], [1, 1, 1], index,
            predicate=pred, label_filter=lf)
        for shards in (1, 2, 8):
            res = ShardedEngine(
                comp, dataclasses.replace(cfg, shards=shards)).run()
            if ref is None:
                ref = res
            assert np.array_equal(ref.result_keys, res.result_keys), \
                (lf, shards)
            assert np.array_equal(ref.result_states, res.result_states), \
                (lf, shards)
