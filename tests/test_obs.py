"""Observability subsystem (DESIGN.md §16): metrics registry semantics,
span-tracer ring buffer + Chrome trace export, no-op identities, engine
instrumentation parity (observe on == observe off, byte-for-byte), and
service-layer metrics with the pure-observer cache-key discipline."""
import dataclasses
import json

import numpy as np
import pytest

import jax

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.data.synthetic_graphs import densifying_graph
from repro.obs import (NOOP, NULL_METRIC, NULL_REGISTRY, NULL_SPAN,
                       NULL_TRACER, MetricsRegistry, Observability,
                       SpanTracer, TOP_LEVEL_SPANS, aggregate, coverage,
                       format_table, log_buckets)
from repro.obs.metrics import DEFAULT_TIME_BUCKETS
from repro.service import DiscoveryRequest, DiscoveryService


# -------------------------------------------------------------- log_buckets
def test_log_buckets_exact_decades():
    assert log_buckets(1e-3, 1.0, per_decade=1) == \
        pytest.approx((1e-3, 1e-2, 1e-1, 1.0))


def test_log_buckets_per_decade_and_validation():
    b = log_buckets(1e-2, 1.0, per_decade=2)
    assert len(b) == 5 and b[0] == pytest.approx(1e-2) \
        and b[-1] == pytest.approx(1.0)
    with pytest.raises(ValueError):
        log_buckets(0, 1.0)
    with pytest.raises(ValueError):
        log_buckets(1.0, 0.5)
    with pytest.raises(ValueError):
        log_buckets(1e-3, 1.0, per_decade=0)


def test_default_time_buckets_span_and_monotone():
    b = DEFAULT_TIME_BUCKETS
    assert b[0] == pytest.approx(1e-6) and b[-1] == pytest.approx(100.0)
    assert all(nxt > cur for cur, nxt in zip(b, b[1:]))


# ---------------------------------------------------------- metric semantics
def test_counter_monotone():
    r = MetricsRegistry()
    c = r.counter("c_total", "help text")
    c.inc()
    c.inc(4)
    c.inc(0.5)
    assert c.value == pytest.approx(5.5)
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_last_write_wins():
    g = MetricsRegistry().gauge("g")
    g.set(7)
    g.inc(3)
    g.set(2)
    assert g.value == 2


def test_histogram_le_semantics():
    # `le` is an *inclusive* upper edge: a value exactly on a bound lands
    # in that bound's bucket, one ulp above lands in the next
    h = MetricsRegistry().histogram("h", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 1.0):        # both <= 1.0
        h.observe(v)
    h.observe(1.0000001)        # (1, 10]
    h.observe(100.0)            # (10, 100]
    h.observe(1e9)              # +Inf overflow bucket
    snap = h.snapshot()
    assert snap["counts"] == [2, 1, 1, 1]
    assert snap["count"] == 5
    assert snap["sum"] == pytest.approx(0.5 + 1.0 + 1.0000001 + 100.0 + 1e9)


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError):
        MetricsRegistry().histogram("h", buckets=(1.0, 1.0, 2.0))
    with pytest.raises(ValueError):
        MetricsRegistry().histogram("h2", buckets=(2.0, 1.0))


def test_registry_get_or_create_and_kind_clash():
    r = MetricsRegistry()
    assert r.counter("x") is r.counter("x")
    with pytest.raises(TypeError):
        r.gauge("x")
    assert r.get("x").kind == "counter"
    assert r.get("missing") is None
    r.gauge("a_gauge")
    assert r.names() == ["a_gauge", "x"]


def test_prometheus_exposition_round_trips():
    r = MetricsRegistry()
    r.counter("steps_total", "total steps").inc(42)
    r.gauge("occupancy").set(17)
    h = r.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = r.to_prometheus()
    lines = text.strip().splitlines()
    assert "# HELP steps_total total steps" in lines
    assert "# TYPE steps_total counter" in lines
    assert "steps_total 42" in lines
    assert "occupancy 17" in lines
    # histogram buckets are cumulative and end with +Inf == count
    assert 'lat_seconds_bucket{le="0.1"} 1' in lines
    assert 'lat_seconds_bucket{le="1"} 2' in lines
    assert 'lat_seconds_bucket{le="+Inf"} 3' in lines
    assert "lat_seconds_count 3" in lines
    # sample values round-trip through float()
    for line in lines:
        if not line.startswith("#"):
            float(line.rsplit(" ", 1)[1])


# ------------------------------------------------------------------- tracer
def test_tracer_records_spans_with_duration():
    t = SpanTracer(capacity=16)
    with t.span("phase.a"):
        pass
    with t.span("phase.b"):
        with t.span("phase.a"):
            pass
    spans = t.spans()
    assert [s[0] for s in spans] == ["phase.a", "phase.a", "phase.b"]
    assert all(s[2] >= 0 for s in spans)
    # nested span closed first, so it precedes its parent in the buffer
    assert t.total_recorded == 3 and t.dropped == 0


def test_tracer_records_span_when_body_raises():
    t = SpanTracer(capacity=4)
    with pytest.raises(RuntimeError):
        with t.span("doomed"):
            raise RuntimeError("boom")
    assert [s[0] for s in t.spans()] == ["doomed"]


def test_tracer_ring_wraparound():
    t = SpanTracer(capacity=4)
    for i in range(10):
        t._record(f"s{i}", float(i), 0.001)
    assert t.total_recorded == 10
    assert t.dropped == 6
    # retained window is the newest 4, oldest first
    assert [s[0] for s in t.spans()] == ["s6", "s7", "s8", "s9"]
    t.clear()
    assert t.spans() == [] and t.total_recorded == 0


def test_chrome_trace_export(tmp_path):
    t = SpanTracer(capacity=8)
    with t.span("engine.step"):
        pass
    path = t.export_chrome_trace(str(tmp_path / "sub" / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    assert len(doc["traceEvents"]) == 1
    ev = doc["traceEvents"][0]
    assert ev["name"] == "engine.step" and ev["ph"] == "X"
    for key in ("ts", "dur", "pid", "tid"):
        assert isinstance(ev[key], (int, float))
    assert ev["dur"] >= 0


# ---------------------------------------------------------------- no-op path
def test_noop_identities():
    assert NOOP.enabled is False
    assert NOOP.metrics is NULL_REGISTRY
    assert NOOP.tracer is NULL_TRACER
    # every metric resolves to the one shared null object
    assert NOOP.counter("anything") is NULL_METRIC
    assert NOOP.gauge("g") is NULL_METRIC
    assert NOOP.histogram("h") is NULL_METRIC
    # and the one shared null span
    assert NOOP.tracer.span("s") is NULL_SPAN
    with NOOP.span("s"):
        pass
    NULL_METRIC.inc()
    NULL_METRIC.set(3)
    NULL_METRIC.observe(0.5)
    assert NULL_METRIC.value == 0 and NULL_METRIC.count == 0
    assert NOOP.tracer.spans() == [] and NOOP.tracer.total_recorded == 0
    assert NULL_REGISTRY.to_prometheus() == ""


def test_noop_export_writes_empty_trace(tmp_path):
    path = NOOP.tracer.export_chrome_trace(str(tmp_path / "t.json"))
    with open(path) as f:
        assert json.load(f)["traceEvents"] == []


def test_snapshot_shapes():
    obs = Observability(max_spans=8)
    obs.counter("c").inc(2)
    with obs.span("s"):
        pass
    snap = obs.snapshot()
    assert snap["enabled"] is True
    assert snap["metrics"]["c"]["value"] == 2
    assert snap["spans"] == {"recorded": 1, "dropped": 0, "capacity": 8}
    json.dumps(snap)   # JSON-serializable end to end
    noop_snap = NOOP.snapshot()
    assert noop_snap["enabled"] is False and noop_snap["metrics"] == {}


# -------------------------------------------------------------------- report
def test_aggregate_and_format_table():
    spans = [("engine.step", 0.0, 0.2, 1), ("engine.step", 0.2, 0.4, 1),
             ("engine.refill", 0.3, 0.1, 1)]
    agg = aggregate(spans)
    assert list(agg) == ["engine.step", "engine.refill"]   # total desc
    assert agg["engine.step"] == {"count": 2, "total_s": pytest.approx(0.6),
                                  "max_s": pytest.approx(0.4)}
    table = format_table(spans, wall_s=1.0)
    assert "engine.step" in table and "% wall" in table
    assert "coverage" in table
    # nested spans excluded from coverage: only engine.step counts here
    assert coverage(spans, 1.0) == pytest.approx(0.6)
    assert coverage(spans, 0.0) == 0.0


# ----------------------------------------------- engine instrumentation
@pytest.fixture(scope="module")
def clique_setup():
    """Spill + refill + late pruning all active (the instrumented paths)."""
    g = densifying_graph(96, 900, seed=0)
    comp = make_clique_computation(g)
    cfg = EngineConfig(k=3, batch=8, pool_capacity=128, max_steps=100_000)
    ref = Engine(comp, cfg).run()
    assert ref.spilled > 0 and ref.refilled > 0
    return comp, cfg, ref


def _require_devices(n: int) -> None:
    if len(jax.devices()) < n:
        pytest.skip(f"needs >= {n} devices (force host devices with "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count={n})")


def _assert_parity(ref, res):
    assert np.array_equal(ref.result_keys, res.result_keys)
    assert np.array_equal(ref.result_states, res.result_states)


@pytest.mark.parametrize("shards", [1, 2, 8])
@pytest.mark.parametrize("T", [1, 16])
def test_observe_parity(clique_setup, shards, T):
    """observe=True is a pure observer: results are byte-identical to the
    unobserved run at every shard count and fusion factor."""
    _require_devices(shards)
    comp, cfg, ref = clique_setup
    obs_cfg = dataclasses.replace(cfg, steps_per_sync=T, observe=True)
    if shards == 1:
        eng = Engine(comp, obs_cfg)
    else:
        from repro.distributed import ShardedEngine
        eng = ShardedEngine(comp, dataclasses.replace(
            obs_cfg, shards=shards))
    res = eng.run()
    _assert_parity(ref, res)
    # the observer actually observed
    m = eng.obs.metrics
    assert m.get("engine_steps_total").value == res.steps
    assert m.get("engine_candidates_total").value > 0
    assert m.get("vpq_spilled_entries_total").value == res.spilled
    assert eng.obs.tracer.total_recorded > 0
    names = {s[0] for s in eng.obs.tracer.spans()}
    assert {"engine.start", "engine.step", "engine.device_compute",
            "engine.host_sync", "engine.finalize"} <= names


def test_observe_off_records_nothing(clique_setup):
    comp, cfg, ref = clique_setup
    eng = Engine(comp, cfg)    # observe defaults off
    res = eng.run()
    _assert_parity(ref, res)
    assert eng.obs is NOOP
    assert eng.obs.tracer.total_recorded == 0


def test_observe_coverage(clique_setup):
    """Top-level spans account for nearly all of an instrumented run's
    wall time (the §16 ≥90% acceptance bar is asserted on the larger
    bench cell; this is the fast smoke floor)."""
    import time
    comp, cfg, _ref = clique_setup
    eng = Engine(comp, dataclasses.replace(cfg, observe=True))
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    spans = eng.obs.tracer.spans()
    cov = coverage(spans, wall)
    assert cov >= 0.85, format_table(spans, wall)
    assert cov <= 1.5   # sanity: not double-counting nested spans


def test_shared_observability_across_engines(clique_setup):
    """EngineConfig.observability injects a shared registry — two engines
    accumulate into the same counters (the service-process pattern)."""
    comp, cfg, _ref = clique_setup
    shared = Observability()
    for _ in range(2):
        Engine(comp, dataclasses.replace(
            cfg, observe=True, observability=shared)).run()
    steps = shared.metrics.get("engine_steps_total").value
    single = Engine(comp, dataclasses.replace(cfg, observe=True))
    single.run()
    assert steps == 2 * single.obs.metrics.get("engine_steps_total").value


def test_checkpoint_spans_and_metrics(clique_setup, tmp_path):
    comp, cfg, ref = clique_setup
    eng = Engine(comp, dataclasses.replace(
        cfg, observe=True, checkpoint_every=20,
        checkpoint_dir=str(tmp_path)))
    res = eng.run()
    _assert_parity(ref, res)
    m = eng.obs.metrics
    assert m.get("checkpoint_saves_total").value > 0
    assert m.get("checkpoint_bytes_written_total").value > 0
    assert m.get("checkpoint_commit_seconds").count > 0
    names = {s[0] for s in eng.obs.tracer.spans()}
    assert {"checkpoint.save", "checkpoint.capture",
            "checkpoint.commit"} <= names


# ------------------------------------------------------------ service layer
@pytest.fixture(scope="module")
def social():
    return densifying_graph(80, 400, seed=3)


def _service(social, **kw):
    svc = DiscoveryService(**kw)
    svc.register_graph("social", social)
    return svc


def test_observe_excluded_from_cache_key(social):
    """observe is a pure observer (same discipline as checkpointing): two
    requests differing only in observe share one cache entry."""
    base = dict(graph="social", workload="clique", k=3, step_budget=50)
    req_off = DiscoveryRequest(**base)
    req_on = DiscoveryRequest(**base, observe=True)
    assert req_off.canonical_spec() == req_on.canonical_spec()
    assert "observe" not in req_on.canonical_spec()

    svc = _service(social, observability=Observability())
    r1 = svc.query(req_on)
    r2 = svc.query(req_off)
    assert r1.status == r2.status == "ok"
    assert not r1.cached and r2.cached
    assert r1.results == r2.results
    assert svc.obs.metrics.get("service_cache_hits_total").value == 1
    assert svc.obs.metrics.get("service_cache_misses_total").value == 1


def test_service_metrics_accumulate(social):
    svc = _service(social, observability=Observability())
    ok = svc.query(DiscoveryRequest(graph="social", workload="clique",
                                    k=3, step_budget=40, observe=True))
    assert ok.status == "ok"
    bad = svc.query(DiscoveryRequest(graph="nope", workload="clique", k=3))
    assert bad.status == "error"
    m = svc.obs.metrics
    assert m.get("service_requests_total").value == 2
    assert m.get("service_validation_errors_total").value == 1
    assert m.get("service_request_seconds").count >= 1
    assert m.get("service_queue_wait_seconds").count >= 1
    # engine steps flowed into the shared registry via the observe knob
    assert m.get("service_engine_steps_total").value == \
        m.get("engine_steps_total").value > 0
    assert ok.stats["straggler_steps"] == 0


def test_service_default_is_noop(social):
    svc = _service(social)
    assert svc.obs is NOOP
    resp = svc.query(DiscoveryRequest(graph="social", workload="clique",
                                      k=3, step_budget=40))
    assert resp.status == "ok"
    assert NOOP.tracer.total_recorded == 0
