import os
# smoke tests and benches must see 1 device (the dry-run sets its own flag)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
