"""Runtime fault-tolerance unit tests (DESIGN.md §15): StragglerMonitor
bounded-memory regression and Heartbeat staleness semantics."""
import time

import pytest

from repro.runtime.fault_tolerance import Heartbeat, StragglerMonitor


# ------------------------------------------------------------ StragglerMonitor
def test_straggler_warmup_never_flags():
    m = StragglerMonitor(threshold=2.0, warmup_steps=3)
    # wildly varying warmup durations must not flag
    assert not m.record(0, 1.0)
    assert not m.record(1, 100.0)
    assert not m.record(2, 0.01)
    assert m.straggler_steps == 0


def test_straggler_flags_slow_step_and_counts():
    m = StragglerMonitor(threshold=2.0, warmup_steps=1)
    m.record(0, 1.0)              # warmup: ema = 1.0
    assert not m.record(1, 1.5)   # below 2x
    assert m.record(2, 10.0)      # straggler
    assert m.straggler_steps == 1
    assert len(m.events) == 1
    step, duration, ema = m.events[0]
    assert step == 2 and duration == 10.0


def test_straggler_ema_not_polluted_by_stragglers():
    # a straggler must not drag the EMA up, else one slow step masks the
    # next: after flagging a 10x step the baseline should be unchanged
    m = StragglerMonitor(threshold=2.0, ema=0.9, warmup_steps=1)
    m.record(0, 1.0)
    ema_before = m.ema
    assert m.record(1, 10.0)
    assert m.ema == ema_before


def test_straggler_events_bounded_total_monotone():
    # regression (PR 8): events grew without bound on long serving runs.
    # The deque keeps only the newest max_events; straggler_steps keeps
    # the monotone total that response stats report.
    m = StragglerMonitor(threshold=2.0, warmup_steps=1, max_events=8)
    m.record(0, 1.0)
    n = 100
    for i in range(1, n + 1):
        assert m.record(i, 50.0)   # every step a straggler (EMA frozen)
    assert m.straggler_steps == n
    assert len(m.events) == 8
    # the retained window is the newest 8
    assert [e[0] for e in m.events] == list(range(n - 7, n + 1))


def test_straggler_default_cap():
    m = StragglerMonitor()
    assert m.events.maxlen == 256


# ------------------------------------------------------------------- Heartbeat
def test_heartbeat_fresh(tmp_path):
    p = str(tmp_path / "hb")
    Heartbeat(p).beat(step=3)
    assert not Heartbeat.is_stale(p, timeout=60.0)


def test_heartbeat_stale(tmp_path):
    p = str(tmp_path / "hb")
    with open(p, "w") as f:
        f.write(f"5 {time.time() - 100.0}")
    assert Heartbeat.is_stale(p, timeout=60.0)
    assert not Heartbeat.is_stale(p, timeout=1000.0)


def test_heartbeat_missing_is_stale(tmp_path):
    assert Heartbeat.is_stale(str(tmp_path / "never-written"), timeout=60.0)


@pytest.mark.parametrize("content", ["", "garbage", "1 2 3", "x y"])
def test_heartbeat_malformed_is_stale(tmp_path, content):
    p = str(tmp_path / "hb")
    with open(p, "w") as f:
        f.write(content)
    assert Heartbeat.is_stale(p, timeout=60.0)


def test_heartbeat_creates_parent_dir(tmp_path):
    p = str(tmp_path / "nested" / "dir" / "hb")
    hb = Heartbeat(p)
    hb.beat(step=1)
    assert not Heartbeat.is_stale(p, timeout=60.0)
