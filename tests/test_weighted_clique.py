"""Maximum-weight clique via the succinct per-subgraph API (paper Table 1 /
Listing-1 style) — exercises from_pointwise end to end."""
import numpy as np
import pytest

from repro.core.engine import Engine, EngineConfig
from repro.core.weighted_clique import (brute_force_max_weight_clique,
                                        make_weighted_clique_computation)
from repro.data.synthetic_graphs import densifying_graph


@pytest.mark.parametrize("seed", [0, 3])
def test_weighted_clique_matches_bruteforce(seed):
    g = densifying_graph(50, 180, seed=seed)
    weights = np.random.default_rng(seed).integers(1, 20, g.n)
    want_w, want_members = brute_force_max_weight_clique(g, weights)
    comp = make_weighted_clique_computation(g, weights)
    res = Engine(comp, EngineConfig(k=1, batch=16, pool_capacity=4096,
                                    max_steps=50000)).run()
    assert int(res.result_keys[0]) == want_w
    members = comp.describe(res.result_states[0])
    assert sum(int(weights[v]) for v in members) == want_w
    for i, u in enumerate(members):
        for v in members[i + 1:]:
            assert g.has_edge(u, v)
