"""Hypothesis property tests on the system's invariants."""
import os

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import bitset
from repro.core.clique import make_clique_computation
from repro.core.graph import GraphStore
from repro.core.patterns import code_key, is_min_code, min_dfs_code
from repro.core.vpq import NEG, VirtualPriorityQueue

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------- bitsets
@given(st.lists(st.integers(0, 199), max_size=64), st.just(200))
def test_bitset_roundtrip(indices, n):
    packed = bitset.from_indices(indices, n)
    dense = np.asarray(bitset.to_bool(jnp.asarray(packed)[None], n))[0]
    want = np.zeros(n, bool)
    want[list(set(indices))] = True
    np.testing.assert_array_equal(dense, want)
    assert int(bitset.popcount(jnp.asarray(packed)[None])[0]) == \
        len(set(indices))


@given(st.integers(1, 130))
def test_lt_mask_table(n):
    table = bitset.lt_mask_table(n)
    dense = np.asarray(bitset.to_bool(jnp.asarray(table), n))
    want = np.arange(n)[None, :] > np.arange(n)[:, None]
    np.testing.assert_array_equal(dense, want)


# ------------------------------------------------------------------- VPQ
@given(st.lists(st.integers(-10**6, 10**6), min_size=1, max_size=300),
       st.sampled_from(["host"]))
def test_vpq_pops_in_priority_order(prios, backend):
    vpq = VirtualPriorityQueue(state_width=2, backend=backend,
                               run_flush_size=32)
    prios = np.asarray(prios, np.int32)
    states = np.stack([prios, prios], 1).astype(np.int32)
    # push in several fragments → multiple runs
    for i in range(0, len(prios), 37):
        sl = slice(i, i + 37)
        vpq.maybe_push(states[sl], prios[sl], prios[sl])
    _, got, _ = vpq.pop_chunk(len(prios))
    np.testing.assert_array_equal(got, np.sort(prios)[::-1])
    assert len(vpq) == 0


@given(st.lists(st.tuples(st.integers(-1000, 1000), st.integers(-1000, 1000)),
                min_size=1, max_size=100))
def test_vpq_late_pruning_drops_dominated(entries):
    vpq = VirtualPriorityQueue(state_width=1, backend="host")
    prios = np.asarray([e[0] for e in entries], np.int32)
    ubs = np.asarray([e[1] for e in entries], np.int32)
    vpq.maybe_push(prios[:, None].copy(), prios, ubs)
    thr = 0
    _, got_p, got_u = vpq.pop_chunk(len(entries), min_ub=thr)
    assert (got_u >= thr).all()
    assert len(got_p) == int((ubs >= thr).sum())


# ------------------------------------------------------- engine invariants
@st.composite
def random_graph(draw):
    n = draw(st.integers(8, 40))
    m = draw(st.integers(n, 3 * n))
    rng = np.random.default_rng(draw(st.integers(0, 10**6)))
    edges = rng.integers(0, n, size=(m, 2))
    return GraphStore.from_edges(n, edges)


@given(random_graph())
def test_clique_ub_anti_monotone(g):
    """API contract: ub(child) <= ub(parent) and result_key <= ub."""
    comp = make_clique_computation(g)
    states, prio, ub = comp.init_frontier()
    rk = comp.result_key(states)
    assert bool(jnp.all(rk <= ub))
    child_prio, child_ub = comp.score_children(states)
    valid = child_prio > jnp.iinfo(jnp.int32).min
    # each child's ub <= its parent's ub
    bound = jnp.where(valid, child_ub, -10**9)
    assert bool(jnp.all(bound <= ub[:, None]))


@given(random_graph())
def test_clique_expansion_canonical(g):
    """Children only add vertices greater than every parent vertex."""
    comp = make_clique_computation(g)
    states, _, _ = comp.init_frontier()
    child_prio, _ = comp.score_children(states)
    valid = np.asarray(child_prio > jnp.iinfo(jnp.int32).min)
    for v in range(g.n):             # seed {v} may only expand to u > v
        assert not valid[v, :v + 1].any()


# ------------------------------------------------------------ DFS codes
@st.composite
def small_pattern(draw):
    nv = draw(st.integers(2, 5))
    labels = [draw(st.integers(0, 2)) for _ in range(nv)]
    edges = {(0, 1)}
    for v in range(2, nv):           # connected: attach each vertex
        u = draw(st.integers(0, v - 1))
        edges.add((u, v))
    extra = draw(st.integers(0, 2))
    for _ in range(extra):
        a = draw(st.integers(0, nv - 1))
        b = draw(st.integers(0, nv - 1))
        if a != b:
            edges.add((min(a, b), max(a, b)))
    return labels, sorted(edges)


@given(small_pattern(), st.integers(0, 10**6))
def test_min_dfs_code_relabel_invariant(pat, seed):
    """The canonical code is invariant under vertex relabeling."""
    labels, edges = pat
    nv = len(labels)
    code1 = min_dfs_code(labels, edges)
    perm = np.random.default_rng(seed).permutation(nv)
    labels2 = [0] * nv
    for v in range(nv):
        labels2[perm[v]] = labels[v]
    edges2 = [(int(perm[a]), int(perm[b])) for a, b in edges]
    code2 = min_dfs_code(labels2, edges2)
    assert code1 == code2
    assert is_min_code(code1)


# ----------------------------------------------- checkpoint round-trip
@given(st.integers(0, 10**6), st.integers(0, 12),
       st.sampled_from(["host", "disk"]), st.integers(1, 3))
def test_checkpoint_roundtrip_preserves_finalize(seed, steps, backend, T):
    """DESIGN.md §15 invariant: ``finalize(restore(snapshot(st)))`` equals
    ``finalize(st)`` for an arbitrary mid-run state — results, counters,
    and the *entire* remaining VPQ content byte-for-byte."""
    import tempfile
    from repro.checkpoint.manager import CheckpointManager
    from repro.core.engine import Engine, EngineConfig

    rng = np.random.default_rng(seed)
    n = int(rng.integers(12, 48))
    g = GraphStore.from_edges(
        n, rng.integers(0, n, size=(int(rng.integers(n, 4 * n)), 2)))
    with tempfile.TemporaryDirectory() as tmp:
        cfg = EngineConfig(k=3, batch=4, pool_capacity=16, spill=backend,
                           spill_dir=os.path.join(tmp, "spill"),
                           steps_per_sync=T)
        eng = Engine(make_clique_computation(g), cfg)
        st_live = eng.start()
        for _ in range(steps):
            if st_live.done:
                break
            eng.step(st_live)
        mgr = CheckpointManager(os.path.join(tmp, "ckpt"))
        eng.save_checkpoint(mgr, st_live, blocking=True)
        st_back = eng.resume(mgr)

        for name in ("steps", "candidates", "expanded", "pruned",
                     "refilled", "syncs", "host_syncs", "threshold",
                     "pool_occupancy", "done"):
            assert getattr(st_back, name) == getattr(st_live, name), name
        assert len(st_back.vpq) == len(st_live.vpq)
        # remaining VPQ drains identically (order and content)
        while len(st_live.vpq):
            s1, p1, u1 = st_live.vpq.pop_chunk(7)
            s2, p2, u2 = st_back.vpq.pop_chunk(7)
            np.testing.assert_array_equal(s1, s2)
            np.testing.assert_array_equal(p1, p2)
            np.testing.assert_array_equal(u1, u2)
        assert len(st_back.vpq) == 0

        r1, r2 = eng.finalize(st_live), eng.finalize(st_back)
        np.testing.assert_array_equal(r1.result_states, r2.result_states)
        np.testing.assert_array_equal(r1.result_keys, r2.result_keys)
        assert (r1.steps, r1.candidates, r1.expanded, r1.pruned) == \
            (r2.steps, r2.candidates, r2.expanded, r2.pruned)
