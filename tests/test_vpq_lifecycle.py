"""VPQ disk-backend lifecycle: spill-file cleanup and ragged run buffers.

Regression tests for the run-file leak where ``pop_chunk`` dropped
exhausted runs without closing them, leaving ``.npy`` spill files on disk
until process exit.
"""
import os

import numpy as np
import pytest

from repro.core.vpq import VirtualPriorityQueue, _Run


def _entries(lo, hi, state_width=6):
    prio = np.arange(lo, hi, dtype=np.int32)
    states = np.repeat(prio[:, None], state_width, 1).astype(np.int32)
    return states, prio, prio.copy()


def _spill_files(d):
    return sorted(f for f in os.listdir(d) if f.endswith(".npy"))


def test_disk_run_files_removed_as_runs_exhaust(tmp_path):
    """Multi-round spill -> refill -> close leaves an empty spill dir, and
    each run's files disappear as soon as the merge exhausts it."""
    d = str(tmp_path)
    vpq = VirtualPriorityQueue(state_width=6, backend="disk", spill_dir=d,
                               buffer_size=16, run_flush_size=32)
    for round_ in range(4):                    # 4 runs of 32 entries each
        s, p, u = _entries(round_ * 32, round_ * 32 + 32)
        vpq.maybe_push(s, p, u)
        vpq._flush_pending()
    assert len(vpq) == 128
    assert len(_spill_files(d)) == 4 * 3       # states/prio/ub per run

    # drain in chunks: the k-way merge empties runs lowest-priority-last;
    # every exhausted run must close (and delete its files) immediately
    seen_files = len(_spill_files(d))
    out = 0
    while len(vpq):
        _, p, _ = vpq.pop_chunk(24)
        out += len(p)
        now = len(_spill_files(d))
        assert now <= seen_files
        seen_files = now
    assert out == 128
    assert _spill_files(d) == [], "exhausted runs leaked spill files"

    # a second spill/refill cycle on the same queue also cleans up
    s, p, u = _entries(0, 40)
    vpq.maybe_push(s, p, u)
    got = vpq.pop_chunk(64)[1]
    assert len(got) == 40
    assert _spill_files(d) == []
    vpq.close()
    assert _spill_files(d) == []


def test_disk_refill_respects_min_ub_and_cleans_up(tmp_path):
    """Late dominance pruning drops entries but still closes their runs."""
    d = str(tmp_path)
    vpq = VirtualPriorityQueue(state_width=4, backend="disk", spill_dir=d,
                               run_flush_size=16)
    s, p, u = _entries(0, 64, state_width=4)
    vpq.maybe_push(s, p, u)
    _, got, _ = vpq.pop_chunk(64, min_ub=32)   # entries with ub < 32 die
    assert list(got) == list(range(63, 31, -1))
    assert len(vpq) == 0
    assert _spill_files(d) == []


@pytest.mark.parametrize("backend", ["host", "disk"])
@pytest.mark.parametrize("n,buffer_size", [(10, 4), (17, 8), (8, 8), (5, 64)])
def test_run_ragged_last_buffer_block(tmp_path, backend, n, buffer_size):
    """_Run block reads: the last buffer block is ragged whenever
    buffer_size does not divide n; pops must cross block boundaries and
    deliver every entry in priority order."""
    prio = np.arange(n, dtype=np.int32)[::-1].copy()   # decreasing
    states = np.repeat(prio[:, None], 3, 1).astype(np.int32)
    run = _Run(states, prio, prio.copy(), backend, str(tmp_path),
               run_id=0, buffer_size=buffer_size)
    got = []
    while not run.exhausted:
        assert run.head_prio() == n - 1 - len(got)
        state, p, ub = run.pop()
        assert list(state) == [p] * 3 and ub == p
        got.append(p)
    assert got == list(range(n - 1, -1, -1))
    run.close()
    assert _spill_files(str(tmp_path)) == []


def test_restored_disk_runs_lifecycle(tmp_path):
    """Spill-file lifecycle across snapshot/restore (DESIGN.md §15):
    ``snapshot`` hardlinks each live run's files into the checkpoint dir
    (referenced, not copied); ``restore`` links them back into a fresh
    live spill dir; the restored queue deletes its OWN links as runs
    exhaust while the checkpoint's files stay intact — so one committed
    checkpoint restores any number of times."""
    live = tmp_path / "live"
    ckpt = tmp_path / "ckpt"
    vpq = VirtualPriorityQueue(state_width=3, backend="disk",
                               spill_dir=str(live), buffer_size=8,
                               run_flush_size=16)
    for round_ in range(3):                    # 3 runs of 16
        s, p, u = _entries(round_ * 16, round_ * 16 + 16, state_width=3)
        vpq.maybe_push(s, p, u)
        vpq._flush_pending()
    s, p, u = _entries(100, 105, state_width=3)
    vpq.maybe_push(s, p, u)                    # + an unflushed pending frag
    vpq.pop_chunk(7)                           # advance cursors mid-buffer

    manifest = vpq.snapshot(str(ckpt))
    ckpt_files = _spill_files(str(ckpt))
    assert ckpt_files, "disk snapshot wrote no run files"
    # referenced, not copied: checkpointed run files share inodes with
    # the live spill files (hardlinks), so big spills snapshot in O(1)
    assert any(os.stat(os.path.join(str(ckpt), f)).st_nlink >= 2
               for f in ckpt_files)

    expect = []
    while len(vpq):
        expect.append(vpq.pop_chunk(11)[1])

    for round_ in range(2):                    # same checkpoint, twice
        spill = tmp_path / f"restored{round_}"
        back = VirtualPriorityQueue.restore(manifest, str(ckpt),
                                            spill_dir=str(spill))
        assert _spill_files(str(spill)), "restore did not link run files"
        seen = len(_spill_files(str(spill)))
        for chunk in expect:                   # byte-identical drain …
            np.testing.assert_array_equal(back.pop_chunk(11)[1], chunk)
            now = len(_spill_files(str(spill)))
            assert now <= seen                 # … deleting links as it goes
            seen = now
        assert len(back) == 0
        back.close()
        assert _spill_files(str(spill)) == [], \
            "restored queue leaked its linked spill files"
        # the checkpoint itself is untouched — restorable again
        assert _spill_files(str(ckpt)) == ckpt_files


def test_restored_host_queue_drains_identically(tmp_path):
    """Host-backend snapshot saves each run's unconsumed remainder; the
    restored queue must drain exactly like the original, including the
    pending fragment and late-pruned accounting."""
    vpq = VirtualPriorityQueue(state_width=2, backend="host",
                               run_flush_size=8)
    rng = np.random.default_rng(3)
    prio = rng.permutation(48).astype(np.int32)
    states = np.repeat(prio[:, None], 2, 1).astype(np.int32)
    vpq.maybe_push(states, prio, prio.copy())
    vpq._flush_pending()
    s, p, u = _entries(60, 63, state_width=2)
    vpq.maybe_push(s, p, u)
    vpq.pop_chunk(5)

    manifest = vpq.snapshot(str(tmp_path / "ckpt"))
    back = VirtualPriorityQueue.restore(manifest, str(tmp_path / "ckpt"))
    assert len(back) == len(vpq)
    while len(vpq):
        a = vpq.pop_chunk(9, min_ub=20)
        b = back.pop_chunk(9, min_ub=20)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
    assert len(back) == 0
    assert back.total_late_pruned == vpq.total_late_pruned


def test_pop_chunk_merges_across_ragged_runs(tmp_path):
    """Interleaved priorities across runs with ragged buffers: the merge
    must yield a globally sorted stream."""
    vpq = VirtualPriorityQueue(state_width=3, backend="disk",
                               spill_dir=str(tmp_path), buffer_size=4,
                               run_flush_size=1)
    rng = np.random.default_rng(0)
    all_prio = rng.permutation(37).astype(np.int32)
    for chunk in np.array_split(all_prio, 5):    # 5 ragged runs
        states = np.repeat(chunk[:, None], 3, 1).astype(np.int32)
        vpq.maybe_push(states, chunk, chunk.copy())
        vpq._flush_pending()
    _, got, _ = vpq.pop_chunk(37)
    assert list(got) == sorted(all_prio.tolist(), reverse=True)
    assert len(vpq) == 0
    assert _spill_files(str(tmp_path)) == []
    vpq.close()
