"""VPQ disk-backend lifecycle: spill-file cleanup and ragged run buffers.

Regression tests for the run-file leak where ``pop_chunk`` dropped
exhausted runs without closing them, leaving ``.npy`` spill files on disk
until process exit.
"""
import os

import numpy as np
import pytest

from repro.core.vpq import VirtualPriorityQueue, _Run


def _entries(lo, hi, state_width=6):
    prio = np.arange(lo, hi, dtype=np.int32)
    states = np.repeat(prio[:, None], state_width, 1).astype(np.int32)
    return states, prio, prio.copy()


def _spill_files(d):
    return sorted(f for f in os.listdir(d) if f.endswith(".npy"))


def test_disk_run_files_removed_as_runs_exhaust(tmp_path):
    """Multi-round spill -> refill -> close leaves an empty spill dir, and
    each run's files disappear as soon as the merge exhausts it."""
    d = str(tmp_path)
    vpq = VirtualPriorityQueue(state_width=6, backend="disk", spill_dir=d,
                               buffer_size=16, run_flush_size=32)
    for round_ in range(4):                    # 4 runs of 32 entries each
        s, p, u = _entries(round_ * 32, round_ * 32 + 32)
        vpq.maybe_push(s, p, u)
        vpq._flush_pending()
    assert len(vpq) == 128
    assert len(_spill_files(d)) == 4 * 3       # states/prio/ub per run

    # drain in chunks: the k-way merge empties runs lowest-priority-last;
    # every exhausted run must close (and delete its files) immediately
    seen_files = len(_spill_files(d))
    out = 0
    while len(vpq):
        _, p, _ = vpq.pop_chunk(24)
        out += len(p)
        now = len(_spill_files(d))
        assert now <= seen_files
        seen_files = now
    assert out == 128
    assert _spill_files(d) == [], "exhausted runs leaked spill files"

    # a second spill/refill cycle on the same queue also cleans up
    s, p, u = _entries(0, 40)
    vpq.maybe_push(s, p, u)
    got = vpq.pop_chunk(64)[1]
    assert len(got) == 40
    assert _spill_files(d) == []
    vpq.close()
    assert _spill_files(d) == []


def test_disk_refill_respects_min_ub_and_cleans_up(tmp_path):
    """Late dominance pruning drops entries but still closes their runs."""
    d = str(tmp_path)
    vpq = VirtualPriorityQueue(state_width=4, backend="disk", spill_dir=d,
                               run_flush_size=16)
    s, p, u = _entries(0, 64, state_width=4)
    vpq.maybe_push(s, p, u)
    _, got, _ = vpq.pop_chunk(64, min_ub=32)   # entries with ub < 32 die
    assert list(got) == list(range(63, 31, -1))
    assert len(vpq) == 0
    assert _spill_files(d) == []


@pytest.mark.parametrize("backend", ["host", "disk"])
@pytest.mark.parametrize("n,buffer_size", [(10, 4), (17, 8), (8, 8), (5, 64)])
def test_run_ragged_last_buffer_block(tmp_path, backend, n, buffer_size):
    """_Run block reads: the last buffer block is ragged whenever
    buffer_size does not divide n; pops must cross block boundaries and
    deliver every entry in priority order."""
    prio = np.arange(n, dtype=np.int32)[::-1].copy()   # decreasing
    states = np.repeat(prio[:, None], 3, 1).astype(np.int32)
    run = _Run(states, prio, prio.copy(), backend, str(tmp_path),
               run_id=0, buffer_size=buffer_size)
    got = []
    while not run.exhausted:
        assert run.head_prio() == n - 1 - len(got)
        state, p, ub = run.pop()
        assert list(state) == [p] * 3 and ub == p
        got.append(p)
    assert got == list(range(n - 1, -1, -1))
    run.close()
    assert _spill_files(str(tmp_path)) == []


def test_pop_chunk_merges_across_ragged_runs(tmp_path):
    """Interleaved priorities across runs with ragged buffers: the merge
    must yield a globally sorted stream."""
    vpq = VirtualPriorityQueue(state_width=3, backend="disk",
                               spill_dir=str(tmp_path), buffer_size=4,
                               run_flush_size=1)
    rng = np.random.default_rng(0)
    all_prio = rng.permutation(37).astype(np.int32)
    for chunk in np.array_split(all_prio, 5):    # 5 ragged runs
        states = np.repeat(chunk[:, None], 3, 1).astype(np.int32)
        vpq.maybe_push(states, chunk, chunk.copy())
        vpq._flush_pending()
    _, got, _ = vpq.pop_chunk(37)
    assert list(got) == sorted(all_prio.tolist(), reverse=True)
    assert len(vpq) == 0
    assert _spill_files(str(tmp_path)) == []
    vpq.close()
