"""Dry-run HLO parsing + roofline math + sharding resolution."""
import numpy as np
import pytest
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

import jax

from repro.analysis.roofline import (HBM_BW, LINK_BW, PEAK_FLOPS,
                                     roofline_terms)
from repro.launch.dryrun import parse_collective_bytes, _extrapolate
from repro.models.sharding import LM_RULES, resolve


HLO = """
ENTRY main {
  %x = f32[128,4096]{1,0} parameter(0)
  %ag = f32[2048,4096]{1,0} all-gather(f32[128,4096]{1,0} %x), dims={0}
  %ar = bf16[512,512]{1,0} all-reduce(bf16[512,512]{1,0} %y), to_apply=%add
  %rs = f32[8,16]{1,0} reduce-scatter(f32[128,16]{1,0} %z), dimensions={0}
  %a2a = f32[64,64]{1,0} all-to-all(f32[64,64]{1,0} %w), dimensions={0}
  %cp = u32[32]{0} collective-permute(u32[32]{0} %v), source_target_pairs={{0,1}}
  %ars = f32[4,4] all-reduce-start(f32[4,4] %q), to_apply=%add
  %ard = f32[4,4] all-reduce-done(f32[4,4] %ars)
}
"""


def test_parse_collective_bytes():
    out = parse_collective_bytes(HLO)
    assert out["all-gather"]["operand_bytes"] == 128 * 4096 * 4
    assert out["all-reduce"]["operand_bytes"] == 512 * 512 * 2 + 4 * 4 * 4
    assert out["all-reduce"]["count"] == 2       # ar + ar-start (done skipped)
    assert out["reduce-scatter"]["operand_bytes"] == 128 * 16 * 4
    assert out["all-to-all"]["operand_bytes"] == 64 * 64 * 4
    assert out["collective-permute"]["operand_bytes"] == 32 * 4


def test_extrapolation_affine():
    m1 = dict(flops=10.0, transcendentals=1.0, bytes_accessed=100.0,
              collectives={"all-reduce": {"count": 2, "operand_bytes": 20}})
    m2 = dict(flops=16.0, transcendentals=1.0, bytes_accessed=130.0,
              collectives={"all-reduce": {"count": 4, "operand_bytes": 40}})
    est = _extrapolate(m1, m2, 2, 4, 10)
    assert est["flops"] == pytest.approx(10 + 8 * 3)       # f(2) + (10-2)*3
    assert est["bytes_accessed"] == pytest.approx(100 + 8 * 15)
    assert est["collectives"]["all-reduce"]["operand_bytes"] == 100


def test_roofline_terms():
    rec = dict(ok=True, arch="a", shape="s", mesh="single",
               mesh_shape={"data": 16, "model": 16},
               meta=dict(kind="train", tokens=1000, active_params=2000,
                         params=2000),
               cost=dict(flops=PEAK_FLOPS, transcendentals=0,
                         bytes_accessed=HBM_BW / 2),
               collectives={"all-reduce": {"count": 1,
                                           "operand_bytes": LINK_BW // 4}},
               memory=dict(peak_bytes=2 ** 30))
    t = roofline_terms(rec)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)
    assert t["collective_s"] == pytest.approx(0.25)
    assert t["dominant"] == "compute"
    assert t["roofline_fraction"] == pytest.approx(1.0)
    assert t["model_flops"] == 6 * 2000 * 1000


def test_resolve_divisibility():
    devs = np.asarray(jax.devices() * 256)[:256].reshape(16, 16)
    mesh = Mesh(devs, ("data", "model"))
    # divisible → sharded
    assert resolve(mesh, LM_RULES, ("vocab",), (256000,)) == P("model")
    # non-divisible → replicated
    assert resolve(mesh, LM_RULES, ("kv_heads",), (2,)) == P(None)
    # tuple axes trimmed to divisible prefix
    spec = resolve(mesh, LM_RULES, ("batch",), (16,))
    assert spec == P("data")          # pod absent, 16 % 16 == 0
    # missing mesh axes dropped silently
    assert resolve(mesh, {"x": "pod"}, ("x",), (64,)) == P(None)
