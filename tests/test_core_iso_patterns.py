"""Subgraph isomorphism + pattern mining vs exact oracles."""
import numpy as np
import pytest

from repro.core.aggregate import (arabesque_style_mining,
                                  max_support_of_size,
                                  topk_frequent_patterns)
from repro.core.engine import Engine, EngineConfig
from repro.core.exhaustive import brute_force_iso, pattern_support_oracle
from repro.core.graph import GraphStore
from repro.core.iso import build_iso_index, make_iso_computation
from repro.core.patterns import (code_vertex_labels, is_min_code,
                                 min_dfs_code)
from repro.data.synthetic_graphs import labeled_graph


QUERIES = [
    ([(0, 1)], [0, 1]),                       # edge
    ([(0, 1), (1, 2)], [0, 1, 2]),            # path
    ([(0, 1), (1, 2), (0, 2)], [1, 1, 1]),    # triangle
    ([(0, 1), (1, 2), (2, 3)], [0, 1, 0, 2]),  # labeled path-4
]


@pytest.mark.parametrize("q_edges,q_labels", QUERIES)
@pytest.mark.parametrize("k", [1, 3])
def test_iso_topk_matches_oracle(q_edges, q_labels, k):
    g = labeled_graph(n=120, m=420, n_labels=3, seed=2)
    oracle = brute_force_iso(g, q_edges, q_labels, induced=True, k=k)
    index = build_iso_index(g, max_hops=3)
    comp = make_iso_computation(g, q_edges, q_labels, index)
    res = Engine(comp, EngineConfig(k=k, batch=64, pool_capacity=8192,
                                    max_steps=50000)).run()
    got = [int(x) for x in res.result_keys if x > -2 ** 31 + 1]
    want = [s for s, _ in oracle]
    assert got == want


def test_iso_index_upper_bound_sound():
    """index[v,l,h] >= degree of any label-l vertex exactly h hops from v."""
    g = labeled_graph(n=80, m=240, n_labels=3, seed=5)
    index = build_iso_index(g, max_hops=3)
    for v in range(0, g.n, 7):
        hops = g.bfs_hops(v, 3)
        for u in range(g.n):
            h = hops[u]
            if 1 <= h <= 3:
                assert index[v, g.labels[u], h - 1] >= g.degrees[u]


def test_pattern_mining_paper_example():
    """The paper's Figure 1b/5 worked example: p4=(b-b-b path), support 3."""
    edges = [(0, 1), (1, 2), (1, 3), (2, 3), (4, 3)]
    labels = [0, 1, 1, 1, 0]
    g = GraphStore.from_edges(5, np.array(edges), labels=np.array(labels))
    res = topk_frequent_patterns(g, m_edges=2, k=1)
    sup, code = res.patterns[0]
    assert sup == 3
    assert code == ((0, 1, 1, 1), (1, 2, 1, 1))
    # 1-edge supports match the paper: f(a-b)=2, f(b-b)=3
    assert pattern_support_oracle(g, [(0, 1)], [0, 1]) == 2
    assert pattern_support_oracle(g, [(0, 1)], [1, 1]) == 3


@pytest.mark.parametrize("m_edges", [2, 3])
def test_pattern_supports_match_oracle(m_edges):
    g = labeled_graph(n=60, m=150, n_labels=3, seed=5)
    res = topk_frequent_patterns(g, m_edges=m_edges, k=3)
    assert res.patterns
    for sup, code in res.patterns:
        vl = code_vertex_labels(code)
        pe = [(i, j) for i, j, _, _ in code]
        assert pattern_support_oracle(g, pe, vl) == sup


def test_nuri_vs_arabesque_threshold_baseline():
    """Abq at T=µ finds the same top pattern; at T=µ/3 it explores more
    candidates (paper §6.3)."""
    g = labeled_graph(n=60, m=180, n_labels=4, seed=8)
    mu = max_support_of_size(g, 3)
    nuri = topk_frequent_patterns(g, m_edges=3, k=1)
    at_mu = arabesque_style_mining(g, m_edges=3, threshold=mu)
    at_mu3 = arabesque_style_mining(g, m_edges=3, threshold=max(1, mu // 3))
    assert at_mu.patterns[0][0] == nuri.patterns[0][0] == mu
    assert at_mu3.candidates >= at_mu.candidates
    assert nuri.patterns[0][0] == at_mu3.patterns[0][0]


def test_min_code_canonical():
    # P3 star form is non-minimal; path form is minimal
    assert not is_min_code(((0, 1, 1, 1), (0, 2, 1, 1)))
    assert is_min_code(((0, 1, 1, 1), (1, 2, 1, 1)))
    # triangle
    assert is_min_code(((0, 1, 0, 0), (1, 2, 0, 0), (2, 0, 0, 0)))
