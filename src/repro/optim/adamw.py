"""AdamW with warmup-cosine schedule and global-norm clipping.

Optimizer state mirrors the parameter pytree (``mu``/``nu`` fp32), so the
same PartitionSpecs shard it (ZeRO-style: optimizer shards wherever the
weight shards; for fully-sharded archs this is ZeRO-3-equivalent under
GSPMD).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(1.0, cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps), 0, 1)
    cos = cfg.peak_lr * (cfg.min_lr_ratio +
                         (1 - cfg.min_lr_ratio) * 0.5 *
                         (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_shapes(param_shapes) -> Dict[str, Any]:
    sds = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_shapes)
    return {"mu": sds, "nu": jax.tree.map(lambda x: x, sds),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        update = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (update + decay *
                                              p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {"mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
                 "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gn, "lr": lr}
