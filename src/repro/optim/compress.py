"""Error-feedback int8 gradient compression for data-parallel all-reduce.

A distributed-optimization trick for the >1000-node regime: per-tensor
scaled int8 quantization before the DP ``psum`` cuts gradient-reduction
bytes 4x; the quantization residual is carried in an error-feedback buffer
(Seide et al. / EF-SGD) so convergence is preserved.  Used inside a
``shard_map``-based train step (the pjit path lets XLA do fp32 reductions);
``tests/test_substrate.py`` checks the EF property: compressed + feedback
converges to the uncompressed mean.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, error_state, axis_name: str):
    """All-reduce-mean int8-compressed gradients with error feedback.

    Returns (reduced fp32 grads, new error state).  Scales are reduced with
    ``pmax`` (shared max-scale) so dequantization is consistent shard-to-
    shard; int8 payloads are summed as int32.
    """
    def one(g, e):
        x = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)) + 1e-12, axis_name)
        scale = amax / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_e = x - _dequantize(q, scale)          # local residual
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total.astype(jnp.float32) * scale) / n, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
