"""graphcast [arXiv:2212.12794]: encoder-processor(16L, d=512)-decoder mesh
GNN, sum aggregator, n_vars=227."""
from repro.configs.base import Arch, GNN_SHAPES, register
from repro.models.gnn import GraphCastConfig


def make_model_cfg(shape):
    s = shape.sizes
    return GraphCastConfig(
        name="graphcast", n_layers=16, d_hidden=512, n_vars=s["d_out"],
        d_in=s["d_feat"], edge_chunks=s["edge_chunks"])


def make_smoke_cfg():
    return GraphCastConfig(name="gc-smoke", n_layers=2, d_hidden=16,
                           n_vars=1, d_in=8, edge_chunks=2)


ARCH = register(Arch(
    name="graphcast", family="gnn", make_model_cfg=make_model_cfg,
    make_smoke_cfg=make_smoke_cfg, shapes=GNN_SHAPES,
    notes="mesh_refinement=6 icosahedral mesh replaced by the benchmark "
          "graph per the shared-shape rule (DESIGN.md §8); n_vars follows "
          "the shape's d_out for node-level tasks, 227 for its native "
          "weather regression"))
