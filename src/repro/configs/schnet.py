"""schnet [arXiv:1706.08566]: 3 interactions d_hidden=64 rbf=300 cutoff=10."""
from repro.configs.base import Arch, GNN_SHAPES, register
from repro.models.gnn import SchNetConfig


def make_model_cfg(shape):
    s = shape.sizes
    return SchNetConfig(
        name="schnet", n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0,
        d_in=s["d_feat"], d_out=s["d_out"], edge_chunks=s["edge_chunks"])


def make_smoke_cfg():
    return SchNetConfig(name="schnet-smoke", d_hidden=16, n_rbf=20, d_in=8,
                        d_out=1, edge_chunks=2)


ARCH = register(Arch(
    name="schnet", family="gnn", make_model_cfg=make_model_cfg,
    make_smoke_cfg=make_smoke_cfg, shapes=GNN_SHAPES))
