"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]:
MoE 24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155,
32 experts top-8."""
from repro.configs.base import Arch, FULL_ATTENTION_SKIP, LM_SHAPES, register
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig


def make_model_cfg(shape=None):
    tokens = (shape.sizes["global_batch"] * shape.sizes["seq_len"]
              if shape is not None and shape.kind in ("train", "prefill")
              else 0)
    chunks = max(1, tokens // 65536)
    return TransformerConfig(
        name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=8, d_ff=512, vocab=49155,
        moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512,
                      token_chunks=chunks))


def make_smoke_cfg():
    return TransformerConfig(
        name="granite-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab=512,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32),
        q_chunk=32, kv_chunk=32, loss_chunk=32)


ARCH = register(Arch(
    name="granite-moe-1b-a400m", family="lm", make_model_cfg=make_model_cfg,
    make_smoke_cfg=make_smoke_cfg, shapes=LM_SHAPES,
    skip_shapes=dict(FULL_ATTENTION_SKIP)))
