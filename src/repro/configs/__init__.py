"""Assigned-architecture registry: ``get_arch(name)`` / ``list_archs()``.

Importing this package registers all ten architectures.
"""
from repro.configs.base import (Arch, ShapeSpec, get_arch, list_archs,
                                round_up)
from repro.configs import (arctic_480b, equiformer_v2, gemma2_9b, glm4_9b,
                           granite_moe_1b, graphcast, mace,
                           phi3_mini_3p8b, schnet, wide_deep)

ALL_ARCHS = sorted(list_archs())
