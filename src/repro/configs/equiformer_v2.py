"""equiformer-v2 [arXiv:2306.12059]: 12L d_hidden=128 l_max=6 m_max=2 8H,
SO(2)-eSCN equivariant graph attention."""
from repro.configs.base import Arch, GNN_SHAPES, register
from repro.models.equivariant import EquiformerV2Config


def make_model_cfg(shape):
    s = shape.sizes
    return EquiformerV2Config(
        name="equiformer-v2", n_layers=12, d_hidden=128, l_max=6, m_max=2,
        n_heads=8, d_in=s["d_feat"], d_out=s["d_out"],
        edge_chunks=s["edge_chunks"])


def make_smoke_cfg():
    return EquiformerV2Config(name="eqv2-smoke", n_layers=2, d_hidden=16,
                              l_max=3, m_max=2, n_heads=4, d_in=8, d_out=1,
                              edge_chunks=2)


ARCH = register(Arch(
    name="equiformer-v2", family="gnn", make_model_cfg=make_model_cfg,
    make_smoke_cfg=make_smoke_cfg, shapes=GNN_SHAPES))
