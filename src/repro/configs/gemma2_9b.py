"""gemma2-9b [arXiv:2408.00118]: dense 42L d_model=3584 16H (GQA kv=8)
d_ff=14336 vocab=256000 — local(4096)+global alternating, logit softcap,
post-norms, head_dim 256.  The one LM arch that RUNS long_500k (hybrid
sub-quadratic: half the layers are 4096-window local)."""
from repro.configs.base import Arch, LM_SHAPES, register
from repro.models.transformer import TransformerConfig


def make_model_cfg(shape=None):
    return TransformerConfig(
        name="gemma2-9b", n_layers=42, d_model=3584, n_heads=16,
        n_kv_heads=8, d_head=256, d_ff=14336, vocab=256000,
        window=4096, local_global=True, use_post_norms=True,
        attn_softcap=50.0, final_softcap=30.0)


def make_smoke_cfg():
    return TransformerConfig(
        name="gemma2-9b-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512, window=16,
        local_global=True, use_post_norms=True, attn_softcap=50.0,
        final_softcap=30.0, q_chunk=32, kv_chunk=32, loss_chunk=32)


ARCH = register(Arch(
    name="gemma2-9b", family="lm", make_model_cfg=make_model_cfg,
    make_smoke_cfg=make_smoke_cfg, shapes=LM_SHAPES,
    notes="long_500k runs: alternating local layers bound half the KV reads "
          "to a 4096 window (static dynamic-slice decode reads)"))
