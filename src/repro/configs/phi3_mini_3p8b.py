"""phi3-mini-3.8b [arXiv:2404.14219]: dense 32L d_model=3072 32H (kv=32)
d_ff=8192 vocab=32064 — RoPE SwiGLU."""
from repro.configs.base import Arch, FULL_ATTENTION_SKIP, LM_SHAPES, register
from repro.models.transformer import TransformerConfig


def make_model_cfg(shape=None):
    return TransformerConfig(
        name="phi3-mini-3.8b", n_layers=32, d_model=3072, n_heads=32,
        n_kv_heads=32, d_ff=8192, vocab=32064)


def make_smoke_cfg():
    return TransformerConfig(
        name="phi3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, q_chunk=32, kv_chunk=32, loss_chunk=32)


ARCH = register(Arch(
    name="phi3-mini-3.8b", family="lm", make_model_cfg=make_model_cfg,
    make_smoke_cfg=make_smoke_cfg, shapes=LM_SHAPES,
    skip_shapes=dict(FULL_ATTENTION_SKIP)))
