"""glm4-9b [hf:THUDM/glm-4-9b]: dense 40L d_model=4096 32H (GQA kv=2)
d_ff=13696 vocab=151552 — RoPE, GQA."""
from repro.configs.base import Arch, FULL_ATTENTION_SKIP, LM_SHAPES, register
from repro.models.transformer import TransformerConfig


def make_model_cfg(shape=None):
    return TransformerConfig(
        name="glm4-9b", n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=151552, rope_theta=10000.0)


def make_smoke_cfg():
    return TransformerConfig(
        name="glm4-9b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, q_chunk=32, kv_chunk=32,
        loss_chunk=32)


ARCH = register(Arch(
    name="glm4-9b", family="lm", make_model_cfg=make_model_cfg,
    make_smoke_cfg=make_smoke_cfg, shapes=LM_SHAPES,
    skip_shapes=dict(FULL_ATTENTION_SKIP)))
