"""wide-deep [arXiv:1606.07792]: 40 sparse fields, embed_dim=32,
MLP 1024-512-256, concat interaction."""
from repro.configs.base import Arch, RECSYS_SHAPES, register
from repro.models.recsys import WideDeepConfig


def make_model_cfg(shape=None):
    return WideDeepConfig(
        name="wide-deep", n_sparse=40, n_dense=13,
        vocab_per_field=1_000_000, embed_dim=32, mlp_dims=(1024, 512, 256))


def make_smoke_cfg():
    return WideDeepConfig(
        name="wd-smoke", n_sparse=8, n_dense=4, vocab_per_field=1000,
        embed_dim=8, mlp_dims=(32, 16))


ARCH = register(Arch(
    name="wide-deep", family="recsys", make_model_cfg=make_model_cfg,
    make_smoke_cfg=make_smoke_cfg, shapes=RECSYS_SHAPES))
