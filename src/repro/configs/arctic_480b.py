"""arctic-480b [hf:Snowflake/snowflake-arctic-base]: MoE 35L d_model=7168
56H (GQA kv=8) d_ff=4864 vocab=32000, 128 experts top-2 + dense residual.

At 480B params the expert weights must be *fully* sharded: the sharding
rules override puts ``expert_ff`` on the ``data`` axis in addition to
``experts`` on ``model`` (2-D expert tensor parallelism / ZeRO-3-like under
GSPMD) so per-chip parameter+optimizer state fits a v5e's 16 GB.
"""
from repro.configs.base import Arch, FULL_ATTENTION_SKIP, LM_SHAPES, register
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

SHARDING_OVERRIDES = {"expert_ff": ("pod", "data")}


def make_model_cfg(shape=None):
    tokens = (shape.sizes["global_batch"] * shape.sizes["seq_len"]
              if shape is not None and shape.kind in ("train", "prefill")
              else 0)
    chunks = max(1, tokens // 65536)
    return TransformerConfig(
        name="arctic-480b", n_layers=35, d_model=7168, n_heads=56,
        n_kv_heads=8, d_ff=4864, vocab=32000,
        moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                      token_chunks=chunks),
        dense_residual=True)


def make_smoke_cfg():
    return TransformerConfig(
        name="arctic-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=512,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64),
        dense_residual=True, q_chunk=32, kv_chunk=32, loss_chunk=32)


ARCH = register(Arch(
    name="arctic-480b", family="lm", make_model_cfg=make_model_cfg,
    make_smoke_cfg=make_smoke_cfg, shapes=LM_SHAPES,
    skip_shapes=dict(FULL_ATTENTION_SKIP)))
