"""mace [arXiv:2206.07697]: 2L d_hidden=128 l_max=2 correlation=3 n_rbf=8
E(3)-ACE higher-order equivariant message passing."""
from repro.configs.base import Arch, GNN_SHAPES, register
from repro.models.equivariant import MACEConfig


def make_model_cfg(shape):
    s = shape.sizes
    return MACEConfig(
        name="mace", n_layers=2, d_hidden=128, l_max=2, correlation=3,
        n_rbf=8, d_in=s["d_feat"], d_out=s["d_out"],
        edge_chunks=s["edge_chunks"])


def make_smoke_cfg():
    return MACEConfig(name="mace-smoke", d_hidden=16, d_in=8, d_out=1,
                      edge_chunks=2)


ARCH = register(Arch(
    name="mace", family="gnn", make_model_cfg=make_model_cfg,
    make_smoke_cfg=make_smoke_cfg, shapes=GNN_SHAPES))
