"""Architecture/shape registry.

Each assigned architecture registers an :class:`Arch`; every ``(arch,
shape)`` pair is a *cell* — the unit the dry-run lowers and the roofline
table reports.  ``kind`` selects the program: ``train`` → ``train_step``,
``prefill``/``decode``/``serve``/``retrieval`` → the serving entry points.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

_REGISTRY: Dict[str, "Arch"] = {}


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                      # train | prefill | decode | serve | retrieval
    sizes: dict


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    family: str                    # lm | gnn | recsys
    make_model_cfg: Callable       # (ShapeSpec | None) -> model config
    make_smoke_cfg: Callable       # () -> reduced config for CPU smoke tests
    shapes: Dict[str, ShapeSpec]
    skip_shapes: Dict[str, str] = dataclasses.field(default_factory=dict)
    notes: str = ""

    def runnable_shapes(self):
        return {k: v for k, v in self.shapes.items()
                if k not in self.skip_shapes}


def register(arch: Arch) -> Arch:
    _REGISTRY[arch.name] = arch
    return arch


def get_arch(name: str) -> Arch:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    return dict(_REGISTRY)


# ---------------------------------------------------------------- LM shapes
LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train",
                          dict(seq_len=4096, global_batch=256)),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill",
                             dict(seq_len=32768, global_batch=32)),
    "decode_32k": ShapeSpec("decode_32k", "decode",
                            dict(seq_len=32768, global_batch=128)),
    "long_500k": ShapeSpec("long_500k", "decode",
                           dict(seq_len=524288, global_batch=1)),
}

FULL_ATTENTION_SKIP = {
    "long_500k": ("pure full-attention arch: 512k-context decode would be a "
                  "full-attention KV read; skipped per brief (run only for "
                  "local/global hybrid gemma2) — see DESIGN.md §5"),
}

# --------------------------------------------------------------- GNN shapes
GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "train", dict(
        n_nodes=2708, n_edges=10556, d_feat=1433, d_out=7, edge_chunks=1)),
    "minibatch_lg": ShapeSpec("minibatch_lg", "train", dict(
        # padded neighbor-sampler output: 1024 seeds, fanout 15-10
        n_nodes=round_up(1024 + 1024 * 15 + 1024 * 150 + 1, 512),
        n_edges=1024 * 15 + 1024 * 150, d_feat=602, d_out=41,
        edge_chunks=4, sampled=True,
        src_nodes=232965, src_edges=114615892, batch_nodes=1024,
        fanout=(15, 10))),
    "ogb_products": ShapeSpec("ogb_products", "train", dict(
        n_nodes=round_up(2449029 + 1, 512),
        n_edges=round_up(61859140, 64 * 512), d_feat=100, d_out=47,
        edge_chunks=64)),
    "molecule": ShapeSpec("molecule", "train", dict(
        n_nodes=30 * 128, n_edges=64 * 128, d_feat=16, d_out=1,
        edge_chunks=1, batch_graphs=128, atoms=30)),
}

# ------------------------------------------------------------ recsys shapes
RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
    "serve_p99": ShapeSpec("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval",
                                dict(batch=1, n_candidates=1_000_000)),
}
