"""Sharded multi-device discovery engine (DESIGN.md §11)."""
from .sharded_engine import (ShardedEngine, ShardedEngineState,
                             shard_map_compat)

__all__ = ["ShardedEngine", "ShardedEngineState", "shard_map_compat"]
