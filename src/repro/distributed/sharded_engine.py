"""Sharded multi-device discovery engine (DESIGN.md §11).

Scales one query across all devices on the host while keeping the paper's
prioritized-expansion/pruning efficiency.  The decomposition follows
density-partitioned distributed subgraph mining (Aridhi et al.,
arXiv:1212.0017): partition-local search plus one small shared bound.

* **seed partitioning** — the initial frontier is dealt round-robin over
  ``shards`` devices (a 1-D ``data`` mesh); every later state stays on the
  shard that materialized its seed ancestor unless the rebalancer moves its
  spilled work.
* **one jitted shard_map super-step** — each shard runs the *identical*
  per-shard body, :meth:`repro.core.engine.Engine._step_impl` (dequeue →
  result merge → prune → targeted expansion → insert), so the single-device
  :class:`~repro.core.engine.Engine` is exactly the 1-shard specialization.
  The only collective inside the step is
  :func:`~repro.core.engine.make_sharded_bound_sync`: each shard's k
  result (state, key) pairs are gathered, identical states deduplicated,
  and the global k-th-best key becomes every shard's dominance threshold
  (k·(S+1) int32 per shard per step — pruning tightness at near-zero
  bandwidth, DESIGN.md §4).
* **per-shard spill** — each shard owns a host/disk
  :class:`~repro.core.vpq.VirtualPriorityQueue`; overflow blocks exit the
  jitted step per shard and refills apply late dominance pruning against
  the *global* threshold.
* **host-side rebalancing** — after refills, shards that cannot refill
  themselves (occupancy below the C/2 watermark, own VPQ empty) pull
  spilled work from the most-loaded VPQs.  The move is a priority-ordered
  k-way merge pop on the donor and a merge-sort insert on the recipient —
  the paper's priority order is preserved by merging, never shuffled.

Result parity is exact by construction: the result merge uses the
canonical total order of :func:`~repro.core.engine.merge_topk` (key
descending, state-words tie-break), and dominance pruning is sound, so any
complete run — single-device or any shard count — discovers every state
whose key reaches the final global threshold and selects the identical
top-k byte-for-byte (parity-asserted in ``tests/test_distributed_engine.py``
and ``benchmarks/bench_distributed.py``).

Host/device division follows the repo-wide rule (DESIGN.md §2): the jitted
shard_map owns every fixed-shape loop; the host only moves overflow /
refill / rebalance blocks and accumulates counters.

Macro-stepping (DESIGN.md §13) composes with sharding: under
``EngineConfig.steps_per_sync = T > 1`` the fused ``while_loop`` of
:meth:`repro.core.engine.Engine._macro_impl` runs *per shard inside one
shard_map*, and the per-shard continue/stop votes are reduced to one
global decision (``psum``) so every shard leaves the loop together and
the in-loop collectives stay aligned.  The loop returns to the host as
soon as *any* shard hits its refill watermark (with spill available
anywhere — the rebalancer can move it), fills its overflow accumulator,
or the fleet drains, so refill and rebalance cadence match the unfused
engine.

Staleness-tolerant bound exchange (DESIGN.md §14): under
``EngineConfig.sync_every = K > 1`` the §4 collective fires only every
K-th inner step; in between, each shard prunes against
``max(last-exchanged global bound, fresh local k-th best)``
(:func:`~repro.core.engine.make_stale_bound_sync`) — both lower bounds on
the fresh global k-th best, so interim pruning is at worst *looser* and
complete runs stay byte-identical for every K while collectives (the
all-gather *and* the exit votes) drop by a factor of K.
``EngineResult.syncs`` counts the exchanges actually run
(``ceil(inner_steps / K)``); ``host_syncs`` counts host round-trips.

Label-constrained computations (DESIGN.md §12) thread through unchanged:
the predicate's bitsets — class rows, allowed-vertex mask, restricted
adjacency — are closure constants of ``score_children``, replicated to
every shard exactly like the adjacency itself, so the sharded engine needs
no label-specific code and the §11 byte-parity argument covers labeled
runs verbatim (asserted in ``tests/test_labeled.py``).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.api import NEG, SubgraphComputation
from repro.core.engine import (Engine, EngineConfig, EngineResult,
                               donatable_pool_argnums,
                               make_sharded_bound_sync,
                               make_stale_bound_sync, merge_topk)
from repro.core.vpq import VirtualPriorityQueue


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` without replication checking, across jax versions:
    ``jax.shard_map(check_vma=)`` (newest), ``jax.shard_map(check_rep=)``,
    or ``jax.experimental.shard_map`` (jax 0.4.x, where the experimental
    module is the only home and ``jax.shard_map`` does not exist)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)

_STAT_KEYS = ("dequeued", "expanded", "created", "pruned",
              "pool_occupancy", "threshold")
_MACRO_STAT_KEYS = ("expanded", "created", "pruned", "pool_occupancy",
                    "threshold", "spill_count", "steps")


@dataclasses.dataclass
class ShardedEngineState:
    """Resumable sharded search state.

    Pool and result arrays are *global* views of the sharded layout:
    leading axis ``shards * per_shard_size``, sharded over the ``data``
    mesh axis by the jitted step.  VPQs and counters are host-side.
    """

    pool_states: jnp.ndarray      # [shards*C, S]
    pool_prio: jnp.ndarray        # [shards*C]
    pool_ub: jnp.ndarray          # [shards*C]
    result_states: jnp.ndarray    # [shards*k, S] (per-shard local top-k)
    result_keys: jnp.ndarray      # [shards*k]
    vpqs: List[VirtualPriorityQueue]
    pool_occupancy: np.ndarray    # [shards] int64
    steps: int = 0
    candidates: int = 0
    expanded: int = 0
    pruned: int = 0
    refilled: int = 0
    rebalanced: int = 0
    syncs: int = 0                # §4 bound-exchange collectives run so far
    host_syncs: int = 0           # host↔device round-trips taken so far
    threshold: int = int(NEG)
    done: bool = False            # every shard pool and VPQ drained
    # per-macro-call bound traces (config.record_bound_trace): each entry
    # is a [shards, inner_steps] int32 pair — threshold actually used /
    # fresh per-step-exchange bound (DESIGN.md §14 invariant, test hook)
    bound_used: List[np.ndarray] = dataclasses.field(default_factory=list)
    bound_fresh: List[np.ndarray] = dataclasses.field(default_factory=list)


class ShardedEngine:
    """Runs one :class:`SubgraphComputation` sharded over a device mesh.

    Drop-in interface parity with :class:`~repro.core.engine.Engine`
    (``start`` / ``step`` / ``finalize`` / ``run``), so the service
    scheduler drives sharded queries unchanged.  ``config.batch`` /
    ``pool_capacity`` / ``max_children`` are per-shard shapes.
    """

    def __init__(self, comp: SubgraphComputation, config: EngineConfig):
        self.comp = comp
        self.cfg = config
        self.shards = config.shards
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        devices = jax.devices()
        if self.shards > len(devices):
            raise ValueError(
                f"shards={self.shards} exceeds the {len(devices)} available "
                f"device(s); force host devices with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count=N "
                f"or lower `shards`")
        self.mesh = Mesh(np.asarray(devices[:self.shards]), ("data",))

        # staleness-tolerant bound exchange (DESIGN.md §14): K inner steps
        # per §4 all-gather.  K is clamped so one K-step segment's overflow
        # always fits an explicitly-sized accumulator, and steps_per_sync
        # is raised to a multiple of K so every fused macro call ends on an
        # exchange boundary — that makes the host-side collective count
        # exactly ceil(total_inner_steps / K) for complete runs.
        if config.sync_every < 1:
            raise ValueError(
                f"sync_every must be >= 1, got {config.sync_every}")
        blk = config.batch + max(config.max_children or 0, comp.num_actions)
        K = config.sync_every
        if config.overflow_accum:
            K = max(1, min(K, config.overflow_accum // blk))
        self.K = K
        T_eff = max(1, config.steps_per_sync)
        if K > 1:   # align fused calls to segment boundaries (forces T > 1)
            T_eff = -(-max(T_eff, K) // K) * K
        if config.record_bound_trace:
            T_eff = max(T_eff, 2)   # traces ride the fused macro path only

        # the per-shard engine: supplies the jit-free super-step body and
        # the derived per-shard shapes (B, C, M, S)
        self._eng = Engine(comp, dataclasses.replace(
            config, shards=1, steps_per_sync=T_eff, sync_every=1))
        self.B, self.C, self.M = self._eng.B, self._eng.C, self._eng.M
        self.S, self.k = self._eng.S, config.k

        # observability (DESIGN.md §16): share the inner engine's instance
        # (dataclasses.replace copied the observe/observability fields) so
        # sharded and per-shard telemetry land in one registry
        self.obs = self._eng.obs
        self._span = self.obs.tracer.span
        self._m_rebalanced = self.obs.counter(
            "engine_rebalanced_total",
            "spilled entries moved across shards")
        self._m_syncs = self.obs.counter(
            "engine_syncs_total", "bound-exchange collectives run")

        sync = make_sharded_bound_sync("data", self.k)
        spec = P("data")

        def body(pool_states, pool_prio, pool_ub, result_states, result_keys):
            (pool_states, pool_prio, pool_ub, result_states, result_keys,
             overflow, stats) = self._eng._step_impl(
                pool_states, pool_prio, pool_ub, result_states, result_keys,
                bound_sync=sync)
            # scalar per-shard stats -> [1] so the mesh axis can concatenate
            stats = {name: stats[name].reshape(1) for name in _STAT_KEYS}
            return (pool_states, pool_prio, pool_ub, result_states,
                    result_keys, overflow, stats)

        self._step_sharded = jax.jit(shard_map_compat(
            body, mesh=self.mesh, in_specs=(spec,) * 5,
            out_specs=((spec,) * 5 + ((spec, spec, spec),
                                      {name: spec for name in _STAT_KEYS}))))
        # refill / rebalance blocks enter through the same merge-sort insert
        # as overflow handling, one fixed [shards*C] block per call
        self._insert_sharded = jax.jit(shard_map_compat(
            self._eng._insert_impl, mesh=self.mesh, in_specs=(spec,) * 6,
            out_specs=(spec,) * 6))

        # fused macro-step (DESIGN.md §13/§14): the per-shard while_loop
        # with the §4 threshold collective at segment heads (every step at
        # K == 1), the stale bound in between, and the per-shard
        # continue/stop votes psum-reduced at segment boundaries so all
        # shards exit together
        self.T = self._eng.T
        if self.T > 1:
            stale = make_stale_bound_sync(self.k)
            rec = bool(config.record_bound_trace)
            stat_keys = _MACRO_STAT_KEYS + (
                ("bound_used", "bound_fresh") if rec else ())

            def any_reduce(flag):
                return jax.lax.psum(flag.astype(jnp.int32), "data") > 0

            def macro_body(pool_states, pool_prio, pool_ub,
                           result_states, result_keys, t_max, vpq_flag,
                           occ0):
                (ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, stats) = \
                    self._eng._macro_impl(
                        pool_states, pool_prio, pool_ub,
                        result_states, result_keys, t_max,
                        vpq_flag[0], occ0[0],
                        bound_sync=sync, any_reduce=any_reduce,
                        sync_every=self.K, stale_sync=stale,
                        record_bounds=rec)
                # scalar per-shard stats -> [1]; [T] traces -> [1, T] so
                # the mesh axis concatenates them to [shards, T]
                stats = {name: stats[name].reshape((1, -1))
                         if name in ("bound_used", "bound_fresh")
                         else stats[name].reshape(1)
                         for name in stat_keys}
                return ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, stats

            self._macro_sharded = jax.jit(shard_map_compat(
                macro_body, mesh=self.mesh,
                in_specs=(spec,) * 5 + (P(), spec, spec),
                out_specs=((spec,) * 8 +
                           ({name: spec for name in stat_keys},))),
                donate_argnums=donatable_pool_argnums())

    # ----------------------------------------------------------------- start
    def start(self) -> ShardedEngineState:
        """Seed-partition the frontier and return a resumable state."""
        with self._span("engine.start"):
            return self._start_impl()

    def _start_impl(self) -> ShardedEngineState:
        cfg, S, C, k, shards = self.cfg, self.S, self.C, self.k, self.shards
        vpqs = []
        for i in range(shards):
            sub = (os.path.join(cfg.spill_dir, f"shard{i}")
                   if cfg.spill_dir is not None else None)
            vpqs.append(VirtualPriorityQueue(
                state_width=S, backend=cfg.spill, spill_dir=sub,
                obs=self.obs))

        states0, prio0, ub0 = (np.asarray(a) for a in
                               self.comp.init_frontier())
        n0 = states0.shape[0]

        pool_states = np.zeros((shards, C, S), np.int32)
        pool_prio = np.full((shards, C), NEG, np.int32)
        pool_ub = np.full((shards, C), NEG, np.int32)
        occ = np.zeros(shards, np.int64)
        for i in range(shards):
            # round-robin seed partition: shard i gets seeds i, i+shards, ...
            s_i, p_i, u_i = states0[i::shards], prio0[i::shards], ub0[i::shards]
            order = np.argsort(p_i.astype(np.int64), kind="stable")[::-1]
            s_i, p_i, u_i = s_i[order], p_i[order], u_i[order]
            m = min(len(p_i), C)
            pool_states[i, :m], pool_prio[i, :m], pool_ub[i, :m] = \
                s_i[:m], p_i[:m], u_i[:m]
            occ[i] = m
            if len(p_i) > m:   # more seeds than per-shard pool slots
                vpqs[i].maybe_push(s_i[m:], p_i[m:], u_i[m:])

        return ShardedEngineState(
            pool_states=jnp.asarray(pool_states.reshape(shards * C, S)),
            pool_prio=jnp.asarray(pool_prio.reshape(shards * C)),
            pool_ub=jnp.asarray(pool_ub.reshape(shards * C)),
            result_states=jnp.zeros((shards * k, S), jnp.int32),
            result_keys=jnp.full((shards * k,), NEG, jnp.int32),
            vpqs=vpqs, pool_occupancy=occ, candidates=int(n0))

    # ------------------------------------------------------------------ step
    def step(self, st: ShardedEngineState,
             max_inner: Optional[int] = None) -> ShardedEngineState:
        """Advance every shard one (macro-)step; spill, refill, rebalance.

        ``max_inner`` caps the fused super-step count exactly like
        :meth:`repro.core.engine.Engine.step` so step budgets truncate at
        the same count for any ``steps_per_sync``.
        """
        shards, cap = self.shards, self._eng.acc_cap
        t0 = time.perf_counter() if self.obs.enabled else 0.0
        if self.T == 1:
            with self._span("engine.step"):
                with self._span("engine.device_compute"):
                    (st.pool_states, st.pool_prio, st.pool_ub,
                     st.result_states, st.result_keys, overflow,
                     stats) = self._step_sharded(
                        st.pool_states, st.pool_prio, st.pool_ub,
                        st.result_states, st.result_keys)
                with self._span("engine.host_sync"):
                    stats = jax.device_get(stats)  # each value: [shards]
                    o_s, o_p, o_u = (np.asarray(a) for a in overflow)
                o_per = len(o_p) // shards

                st.steps += 1
                st.syncs += 1          # one §4 exchange per unfused step
                st.host_syncs += 1
                st.expanded += int(stats["expanded"].sum())
                st.candidates += int(stats["created"].sum())
                st.pruned += int(stats["pruned"].sum())
                st.threshold = int(stats["threshold"][0])  # replicated, §4
                occ = stats["pool_occupancy"].astype(np.int64)

                with self._span("engine.spill"):
                    for i in range(shards):
                        sl = slice(i * o_per, (i + 1) * o_per)
                        st.vpqs[i].maybe_push(o_s[sl], o_p[sl], o_u[sl])
                st = self._refill_rebalance(st, occ)
            self._after_step(st, 1, 1, stats, t0)
            return st

        t_cap = (self.T if max_inner is None
                 else max(1, min(self.T, int(max_inner))))
        with self._span("engine.step"):
            with self._span("engine.device_compute"):
                (st.pool_states, st.pool_prio, st.pool_ub,
                 st.result_states, st.result_keys, acc_s, acc_p, acc_u,
                 stats) = self._macro_sharded(
                    st.pool_states, st.pool_prio, st.pool_ub,
                    st.result_states, st.result_keys, np.int32(t_cap),
                    np.asarray([len(v) > 0 for v in st.vpqs]),
                    st.pool_occupancy.astype(np.int32))
            with self._span("engine.host_sync"):
                stats = jax.device_get(stats)     # each value: [shards]
            n = int(stats["steps"][0])            # uniform: global exit vote
            st.steps += n
            # every segment opens with one fresh exchange and runs <= K
            # steps, and fused calls end on segment boundaries (T is a
            # multiple of K), so this call ran exactly ceil(n / K)
            # collectives
            st.syncs += -(-n // self.K)
            st.host_syncs += 1
            if self.cfg.record_bound_trace:
                st.bound_used.append(np.asarray(stats["bound_used"])[:, :n])
                st.bound_fresh.append(
                    np.asarray(stats["bound_fresh"])[:, :n])
            st.expanded += int(stats["expanded"].sum())
            st.candidates += int(stats["created"].sum())
            st.pruned += int(stats["pruned"].sum())
            st.threshold = int(stats["threshold"][0])
            occ = stats["pool_occupancy"].astype(np.int64)
            spill = stats["spill_count"]
            if spill.any():   # ship each shard's valid accumulator prefix
                acc_s, acc_p, acc_u = (np.asarray(a)
                                       for a in (acc_s, acc_p, acc_u))
                with self._span("engine.spill"):
                    for i in range(shards):
                        w = int(spill[i])
                        if w:
                            base = i * cap
                            st.vpqs[i].maybe_push(acc_s[base:base + w],
                                                  acc_p[base:base + w],
                                                  acc_u[base:base + w])
            st = self._refill_rebalance(st, occ)
        self._after_step(st, n, -(-n // self.K), stats, t0)
        return st

    def _after_step(self, st: ShardedEngineState, n_steps: int,
                    n_syncs: int, stats: dict, t0: float) -> None:
        """Record one step() call's metrics (no-op handles when off)."""
        eng = self._eng
        eng._m_steps.inc(n_steps)
        eng._m_host_syncs.inc()
        self._m_syncs.inc(n_syncs)
        eng._m_expanded.inc(int(stats["expanded"].sum()))
        eng._m_candidates.inc(int(stats["created"].sum()))
        eng._m_pruned.inc(int(stats["pruned"].sum()))
        eng._g_occupancy.set(int(st.pool_occupancy.sum()))
        eng._g_threshold.set(st.threshold)
        if self.obs.enabled:
            eng._h_step.observe(time.perf_counter() - t0)

    # ----------------------------------------------------- refill/rebalance
    def _refill_rebalance(self, st: ShardedEngineState,
                          occ: np.ndarray) -> ShardedEngineState:
        shards, C, S = self.shards, self.C, self.S
        # ---- refill: per shard, below the C/2 watermark, from its own VPQ
        blk_s = np.zeros((shards, C, S), np.int32)
        blk_p = np.full((shards, C), NEG, np.int32)
        blk_u = np.full((shards, C), NEG, np.int32)
        fill = np.zeros(shards, np.int64)
        if any(occ[i] < C // 2 and len(st.vpqs[i]) for i in range(shards)):
            with self._span("engine.refill"):
                for i in range(shards):
                    if occ[i] < C // 2 and len(st.vpqs[i]):
                        r_s, r_p, r_u = st.vpqs[i].pop_chunk(
                            C - int(occ[i]), min_ub=st.threshold)
                        r = len(r_p)
                        if r:
                            blk_s[i, :r], blk_p[i, :r], blk_u[i, :r] = \
                                r_s, r_p, r_u
                            fill[i] = r
                            st.refilled += r
                            self._eng._m_refilled.inc(r)

        # ---- rebalance: shards that cannot refill themselves pull spilled
        # work from the most-loaded VPQs (priority order preserved: the
        # donor pop is a sorted k-way merge, the insert a merge-sort)
        needy = [i for i in range(shards)
                 if occ[i] + fill[i] < C // 2 and len(st.vpqs[i]) == 0]
        if needy:
            with self._span("engine.rebalance"):
                donors = sorted(
                    (i for i in range(shards) if len(st.vpqs[i])),
                    key=lambda i: -len(st.vpqs[i]))
                for i in needy:
                    for d in donors:
                        room = C // 2 - int(occ[i] + fill[i])
                        if room <= 0:
                            break
                        if not len(st.vpqs[d]):
                            continue
                        m_s, m_p, m_u = st.vpqs[d].pop_chunk(
                            min(room, len(st.vpqs[d])), min_ub=st.threshold)
                        m = len(m_p)
                        if m:
                            off = int(fill[i])
                            blk_s[i, off:off + m] = m_s
                            blk_p[i, off:off + m] = m_p
                            blk_u[i, off:off + m] = m_u
                            fill[i] += m
                            st.rebalanced += m
                            self._m_rebalanced.inc(m)

        if fill.any():
            (st.pool_states, st.pool_prio, st.pool_ub, ov_s, ov_p, ov_u) = \
                self._insert_sharded(
                    st.pool_states, st.pool_prio, st.pool_ub,
                    jnp.asarray(blk_s.reshape(shards * C, S)),
                    jnp.asarray(blk_p.reshape(shards * C)),
                    jnp.asarray(blk_u.reshape(shards * C)))
            # occ + fill <= C by construction, so the insert overflow is
            # all-NEG padding; push defensively anyway
            ov_s, ov_p, ov_u = (np.asarray(a) for a in (ov_s, ov_p, ov_u))
            per = len(ov_p) // shards
            for i in range(shards):
                sl = slice(i * per, (i + 1) * per)
                st.vpqs[i].maybe_push(ov_s[sl], ov_p[sl], ov_u[sl])

        st.pool_occupancy = occ + fill
        st.done = bool((st.pool_occupancy == 0).all()
                       and all(len(v) == 0 for v in st.vpqs))
        return st

    # -------------------------------------------------------------- finalize
    def finalize(self, st: ShardedEngineState) -> EngineResult:
        """Merge per-shard result sets canonically, close VPQs, package."""
        with self._span("engine.finalize"):
            return self._finalize_impl(st)

    def _finalize_impl(self, st: ShardedEngineState) -> EngineResult:
        result_states, result_keys = merge_topk(
            st.result_states, st.result_keys, self.k)
        per_shard = dict(
            spilled=[int(v.total_spilled) for v in st.vpqs],
            late_pruned=[int(v.total_late_pruned) for v in st.vpqs],
            vpq_backlog=[len(v) for v in st.vpqs],
            pool_occupancy=[int(x) for x in st.pool_occupancy])
        if self.cfg.record_bound_trace:
            # [shards, total_inner_steps] traces as per-shard lists
            used = (np.concatenate(st.bound_used, axis=1) if st.bound_used
                    else np.zeros((self.shards, 0), np.int32))
            fresh = (np.concatenate(st.bound_fresh, axis=1)
                     if st.bound_fresh
                     else np.zeros((self.shards, 0), np.int32))
            per_shard["bound_used"] = [list(map(int, row)) for row in used]
            per_shard["bound_fresh"] = [list(map(int, row))
                                        for row in fresh]
        for v in st.vpqs:
            v.close()
        return EngineResult(
            result_states=np.asarray(result_states),
            result_keys=np.asarray(result_keys),
            steps=st.steps, candidates=st.candidates, expanded=st.expanded,
            pruned=st.pruned,
            spilled=sum(per_shard["spilled"]), refilled=st.refilled,
            rebalanced=st.rebalanced,
            late_pruned=sum(per_shard["late_pruned"]), syncs=st.syncs,
            host_syncs=st.host_syncs, per_shard=per_shard)

    # ------------------------------------------------------- checkpointing
    _CKPT_SCALARS = ("steps", "candidates", "expanded", "pruned", "refilled",
                     "rebalanced", "syncs", "host_syncs", "threshold", "done")

    def save_checkpoint(self, mgr, st: ShardedEngineState,
                        blocking: bool = False) -> None:
        """Persist a sharded state: one manifest covers every shard, with
        per-shard VPQ snapshots under ``vpq/shard{i}`` subdirs of the step
        directory (DESIGN.md §15).  ``record_bound_trace`` journals are a
        test hook and are not checkpointed."""
        scalars = {name: getattr(st, name) for name in self._CKPT_SCALARS}
        scalars["pool_occupancy"] = [int(x) for x in st.pool_occupancy]

        def capture(tmp_dir: str) -> dict:
            vpqs = [v.snapshot(os.path.join(tmp_dir, "vpq", f"shard{i}"))
                    for i, v in enumerate(st.vpqs)]
            return {"kind": "sharded_engine", "shards": self.shards,
                    "scalars": scalars, "vpqs": vpqs}

        tree = dict(pool_states=st.pool_states, pool_prio=st.pool_prio,
                    pool_ub=st.pool_ub, result_states=st.result_states,
                    result_keys=st.result_keys)
        mgr.save(st.steps, tree, blocking=blocking, capture=capture)

    def resume(self, source,
               step: Optional[int] = None) -> ShardedEngineState:
        """Rebuild a :class:`ShardedEngineState` whose continued run is
        byte-identical to an uninterrupted one.  The checkpoint must have
        been written at the same shard count."""
        from repro.checkpoint.manager import CheckpointManager
        mgr = (source if isinstance(source, CheckpointManager)
               else CheckpointManager(source, obs=self.obs))
        manifest = mgr.read_manifest(step)
        step = manifest["step"]
        extra = manifest["extra"]
        if extra is None or extra.get("kind") != "sharded_engine":
            raise ValueError(
                f"step {step} in {mgr.dir} is not a sharded-engine "
                f"checkpoint")
        if extra["shards"] != self.shards:
            raise ValueError(
                f"checkpoint written at shards={extra['shards']}, engine "
                f"configured with shards={self.shards}")
        like = {name: np.zeros(
            [int(s) for s in leaf["shape"]], np.dtype(leaf["dtype"]))
            for leaf in manifest["leaves"]
            for name in [leaf["name"]]}
        tree = mgr.restore(like, step=step)
        vpqs = []
        for i, vman in enumerate(extra["vpqs"]):
            sub = (os.path.join(self.cfg.spill_dir, f"shard{i}")
                   if self.cfg.spill_dir is not None else None)
            vpqs.append(VirtualPriorityQueue.restore(
                vman, os.path.join(mgr.path(step), "vpq", f"shard{i}"),
                spill_dir=sub, obs=self.obs))
        scalars = dict(extra["scalars"])
        occ = np.asarray(scalars.pop("pool_occupancy"), np.int64)
        return ShardedEngineState(
            pool_states=jnp.asarray(tree["pool_states"]),
            pool_prio=jnp.asarray(tree["pool_prio"]),
            pool_ub=jnp.asarray(tree["pool_ub"]),
            result_states=jnp.asarray(tree["result_states"]),
            result_keys=jnp.asarray(tree["result_keys"]),
            vpqs=vpqs, pool_occupancy=occ, **scalars)

    # ------------------------------------------------------------------- run
    def run(self, progress_every: int = 0,
            resume: bool = False) -> EngineResult:
        """Run to completion, with the same periodic-checkpoint / resume
        contract as :meth:`repro.core.engine.Engine.run`."""
        mgr = None
        if self.cfg.checkpoint_dir and (self.cfg.checkpoint_every > 0
                                        or resume):
            from repro.checkpoint.manager import CheckpointManager
            mgr = CheckpointManager(self.cfg.checkpoint_dir, obs=self.obs)
        st = None
        if resume and mgr is not None and mgr.latest_step() is not None:
            st = self.resume(mgr)
        if st is None:
            st = self.start()
        every = self.cfg.checkpoint_every
        last_ckpt = st.steps
        while not st.done and st.steps < self.cfg.max_steps:
            self.step(st, max_inner=self.cfg.max_steps - st.steps)
            if progress_every and st.steps % progress_every == 0:
                print(f"[{self.comp.name}/x{self.shards}] step={st.steps} "
                      f"occ={st.pool_occupancy.tolist()} "
                      f"vpq={[len(v) for v in st.vpqs]} "
                      f"thr={st.threshold} cand={st.candidates}")
            if mgr is not None and every > 0 and \
                    st.steps - last_ckpt >= every:
                self.save_checkpoint(mgr, st)
                last_ckpt = st.steps
        if mgr is not None and every > 0 and st.steps > last_ckpt:
            self.save_checkpoint(mgr, st)
        if mgr is not None:
            mgr.wait()
        return self.finalize(st)
