"""Shared transformer layers: RMSNorm, RoPE, GQA attention (training/prefill
via memory-bounded chunked online-softmax, decode via KV cache with optional
sliding window), logit soft-capping.

Precision policy (MaxText-style): parameters live in fp32; matmul inputs are
cast to bf16 with fp32 accumulation (``preferred_element_type``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

BF16 = jnp.bfloat16


def mm(x, w):
    """bf16 matmul with fp32 accumulation."""
    return jax.lax.dot_general(
        x.astype(BF16), w.astype(BF16),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * (1.0 + scale)).astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None or cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# ------------------------------------------------------------------- rotary
def rope_frequencies(d_head: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [..., T, H, D]; positions: broadcastable to [..., T]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)              # [..., T, 1, D/2]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# -------------------------------------------------- chunked (flash) attention
def chunked_attention(q, k, v, *, causal: bool = True,
                      window=None,
                      logit_cap=None,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      q_offset: int = 0):
    """Online-softmax attention, flash-style custom VJP (see models/flash.py:
    forward saves only (out, logsumexp); backward recomputes scores per
    tile).  q: [B, Tq, Hq, D]; k, v: [B, Tk, Hkv, D]; GQA via Hq % Hkv == 0;
    ``window`` may be a traced scalar."""
    from .flash import flash_attention
    return flash_attention(q, k, v, causal=causal, window=window,
                           logit_cap=logit_cap, q_chunk=q_chunk,
                           kv_chunk=kv_chunk, q_offset=q_offset)


# -------------------------------------------------------------------- decode
def decode_attention(q, k_cache, v_cache, position, *,
                     window: Optional[int] = None,
                     logit_cap: Optional[float] = None):
    """Single-token attention against a KV cache.

    q: [B, Hq, D]; k_cache, v_cache: [B, S, Hkv, D]; position: scalar int
    (index of the new token; cache entries >= position are invalid).
    With ``window``, only the last ``window`` cache slots are read
    (static-size dynamic slice — sub-quadratic local layers).
    """
    b, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    if window is not None and window < s:
        start = jnp.clip(position - (window - 1), 0, s - window)
        k_cache = jax.lax.dynamic_slice_in_dim(k_cache, start, window, 1)
        v_cache = jax.lax.dynamic_slice_in_dim(v_cache, start, window, 1)
        k_pos = start + jnp.arange(window)
    else:
        k_pos = jnp.arange(s)

    qh = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qh.astype(BF16),
                        k_cache.astype(BF16),
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, logit_cap)
    valid = k_pos <= position
    scores = jnp.where(valid[None, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(BF16),
                     v_cache.astype(BF16),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d)
