"""Memory-bounded accumulation scans.

``lax.scan``'s reverse-mode saves the carry at *every* step — for a pure
accumulation (``acc += f(chunk)``) that stores n_chunks copies of the
accumulator (measured: 15 GiB/device for EquiformerV2 on ogb_products).
:func:`sum_scan` exploits linearity: d(acc) passes through every chunk
unchanged, so the backward is a second scan that replays each chunk's VJP
against the SAME cotangent — zero carry residuals.

``fn`` may close over parameters/activations; ``jax.closure_convert``
exposes them so their cotangents accumulate correctly.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp


def _float0_like(x):
    if jnp.issubdtype(x.dtype, jnp.floating) or \
            jnp.issubdtype(x.dtype, jnp.complexfloating):
        return jnp.zeros_like(x)
    return np.zeros(x.shape, jax.dtypes.float0)


def sum_scan(fn, xs, dc_fix=None):
    """Return ``Σ_i fn(xs[i])`` where ``xs`` is a pytree of ``[n, ...]``
    arrays (chunk-major).  Output may be any pytree of float arrays.

    Backward memory: one cotangent + one chunk VJP at a time (vs. scan's
    n_chunks saved carries).  ``dc_fix(primal_const, cotangent)`` lets the
    caller pin shardings on the backward accumulators (GSPMD otherwise
    replicates the zero-initialized carry through the while loop).
    """
    x0 = jax.tree.map(lambda a: a[0], xs)
    conv, consts = jax.closure_convert(fn, x0)
    return _sum_scan_inner(conv, dc_fix, xs, list(consts))


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _sum_scan_inner(conv, dc_fix, xs, consts):
    def body(acc, xc):
        delta = conv(xc, *consts)
        return jax.tree.map(jnp.add, acc, delta), None

    x0 = jax.tree.map(lambda a: a[0], xs)
    init = jax.tree.map(jnp.zeros_like,
                        jax.eval_shape(lambda c: conv(c, *consts), x0))
    acc, _ = jax.lax.scan(body, init, xs)
    return acc


def _fwd(conv, dc_fix, xs, consts):
    return _sum_scan_inner(conv, dc_fix, xs, consts), (xs, consts)


def _bwd(conv, dc_fix, res, g):
    xs, consts = res

    def body(dc_acc, xc):
        _, pullback = jax.vjp(lambda xc_, cs: conv(xc_, *cs), xc,
                              list(consts))
        dxc, dcs = pullback(g)
        dc_acc = jax.tree.map(
            lambda a, b: a if b is None else a + b, dc_acc, dcs)
        if dc_fix is not None:
            dc_acc = [dc_fix(c, d) for c, d in zip(consts, dc_acc)]
        return dc_acc, dxc

    dc0 = [jnp.zeros(c.shape, c.dtype) if jnp.issubdtype(
        c.dtype, jnp.floating) else jnp.zeros(c.shape, jnp.float32)
        for c in consts]
    if dc_fix is not None:
        dc0 = [dc_fix(c, d) for c, d in zip(consts, dc0)]
    dconsts, dxs = jax.lax.scan(body, dc0, xs)
    # integer leaves (edge indices) carry float0 cotangents
    dxs = jax.tree.map(
        lambda x, dx: dx if jnp.issubdtype(x.dtype, jnp.floating)
        else np.zeros(x.shape, jax.dtypes.float0), xs, dxs)
    dconsts = [np.zeros(c.shape, jax.dtypes.float0)
               if not jnp.issubdtype(c.dtype, jnp.floating) else d
               for c, d in zip(consts, dconsts)]
    return dxs, list(dconsts)


_sum_scan_inner.defvjp(_fwd, _bwd)
