"""Equivariant GNNs: MACE [arXiv:2206.07697] and an eSCN-style EquiformerV2
[arXiv:2306.12059].

Irrep features are laid out ``[N, (l_max+1)^2, C]`` (flat (l,m) index, C
channels).  Real spherical harmonics up to l=6 are evaluated from cartesian
unit vectors with the associated-Legendre recurrence (no lookup tables, pure
jnp, grad-safe).

Faithfulness notes (also in DESIGN.md §8):
* MACE — 2-layer ACE: Bessel radial basis (8), Y_lm up to l=2, per-channel
  density ``A_i`` via radial-weighted scatter of neighbor channels, product
  basis to correlation order 3 built from rotation-invariant contractions
  (B1 = scalar channel, B2_l = ||A_l||², B3_l = ||A_l||²·A_0) — a structural
  simplification of the full Clebsch-Gordan symmetric contraction that keeps
  the compute regime (gather → per-edge tensor ops → scatter → per-node
  contraction) and correlation-order scaling.
* EquiformerV2 — the eSCN insight is implemented structurally: messages mix
  across l *within each m block*, restricted to |m| <= m_max (2), with
  radial modulation; attention weights come from the invariant (l=0)
  channels via a per-head MLP + segment softmax.  The Wigner-D rotation into
  the edge frame is replaced by operating directly in the global frame
  (same block-sparse compute pattern; the rotation is a per-edge unitary
  that does not change FLOP structure).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from .gnn import (_mlp_shapes, chunk_edges, constrain0, cosine_cutoff,
                  edge_geometry_chunk, edge_scan, mlp, segment_sum,
                  sum_edge_scan)
from .layers import mm


# ---------------------------------------------------- real spherical harmonics
def real_sph_harm(l_max: int, vec: jnp.ndarray) -> jnp.ndarray:
    """Real spherical harmonics Y_lm for unit vectors ``vec [E, 3]``.

    Returns [E, (l_max+1)^2] ordered (l, m) with m = -l..l.
    Uses P̃_l^m(z) = P_l^m / sin^m θ (polynomials in z) and
    c_m = Re[(x+iy)^m], s_m = Im[(x+iy)^m], so no trig of angles is needed.
    """
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    # c_m, s_m recurrences
    c = [jnp.ones_like(x)]
    s = [jnp.zeros_like(x)]
    for m in range(1, l_max + 1):
        cm = c[-1] * x - s[-1] * y
        sm = s[-1] * x + c[-1] * y
        c.append(cm)
        s.append(sm)
    # P̃_l^m recurrences
    ptilde: Dict[tuple, jnp.ndarray] = {(0, 0): jnp.ones_like(z)}
    for m in range(1, l_max + 1):
        ptilde[(m, m)] = ptilde[(m - 1, m - 1)] * (2 * m - 1)
    for m in range(0, l_max):
        ptilde[(m + 1, m)] = z * (2 * m + 1) * ptilde[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            ptilde[(l, m)] = ((2 * l - 1) * z * ptilde[(l - 1, m)] -
                              (l - 1 + m) * ptilde[(l - 2, m)]) / (l - m)
    out = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            norm = math.sqrt((2 * l + 1) / (4 * math.pi) *
                             math.factorial(l - am) / math.factorial(l + am))
            if m != 0:
                norm *= math.sqrt(2.0)
            base = norm * ptilde[(l, am)]
            out.append(base * (c[am] if m >= 0 else s[am]))
    return jnp.stack(out, axis=-1)


def lm_tables(l_max: int):
    ls, ms = [], []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            ls.append(l)
            ms.append(m)
    return np.asarray(ls), np.asarray(ms)


def bessel_rbf(dist, n_rbf: int, cutoff: float):
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    d = jnp.maximum(dist[:, None], 1e-6)
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * math.pi * d / cutoff) / d


# ======================================================================
# MACE
# ======================================================================
@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0
    d_in: int = 16
    d_out: int = 1
    edge_chunks: int = 1
    node_shard: tuple = None
    edge_shard: tuple = None
    feat_shard: tuple = None

    @property
    def n_lm(self) -> int:
        return (self.l_max + 1) ** 2


def mace_param_shapes(cfg: MACEConfig):
    sd = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    c, nl = cfg.d_hidden, cfg.l_max + 1
    n_inv = 1 + nl * (cfg.correlation - 1)     # B1 + B2_l + B3_l blocks
    out = {"embed_w": sd(cfg.d_in, c), "embed_b": sd(c)}
    for i in range(cfg.n_layers):
        out.update(_mlp_shapes(f"radial{i}", (cfg.n_rbf, c, c * nl), sd))
        out[f"mix{i}_w"] = sd(n_inv * c, c)
        out[f"mix{i}_b"] = sd(c)
    out.update(_mlp_shapes("readout", (c, c, cfg.d_out), sd))
    return out


def mace_forward(cfg: MACEConfig, params, batch):
    n = batch["features"].shape[0]
    pos = batch["positions"]
    edges = chunk_edges((batch["edge_src"], batch["edge_dst"]),
                        cfg.edge_chunks)
    ls, _ = lm_tables(cfg.l_max)
    l_of = jnp.asarray(ls)
    c, nl = cfg.d_hidden, cfg.l_max + 1

    h = constrain0(mm(batch["features"], params["embed_w"]) +
                   params["embed_b"], cfg.node_shard, cfg.feat_shard)
    for i in range(cfg.n_layers):
        def chunk(ec, _i=i):
            src_c, dst_c = ec
            vec, dist = edge_geometry_chunk(pos, src_c, dst_c)
            rhat = vec / jnp.maximum(dist[:, None], 1e-6)
            sh = real_sph_harm(cfg.l_max, rhat)              # [e, n_lm]
            rbf = bessel_rbf(dist, cfg.n_rbf, cfg.cutoff) * \
                cosine_cutoff(dist, cfg.cutoff)[:, None]
            rad = mlp(f"radial{_i}", params, rbf, 2).reshape(-1, c, nl)
            rad_lm = rad[:, :, l_of]                          # [e,C,n_lm]
            # density A_i[c, lm] = Σ_j rad[e,c,lm] · Y[e,lm] · h_j[c]
            edge_val = rad_lm * sh[:, None, :] * h[src_c][:, :, None]
            return segment_sum(edge_val, dst_c, n)

        A = constrain0(sum_edge_scan(chunk, edges, cfg.edge_chunks, n,
                                     cfg.node_shard),
                       cfg.node_shard)   # [N,C,n_lm]: lm last → no feat axes
        # invariant product basis (correlation 1..3)
        b1 = A[:, :, 0]                                              # ν=1
        b2 = segment_sum(jnp.square(A).transpose(2, 0, 1), l_of, nl) \
            .transpose(1, 2, 0)                                      # [N,C,L+1]
        b3 = b2 * A[:, :, 0:1]                                       # ν=3
        inv = jnp.concatenate(
            [b1[:, :, None], b2, b3], axis=-1).reshape(n, -1)
        msg = mm(inv, params[f"mix{i}_w"]) + params[f"mix{i}_b"]
        h = h + jax.nn.silu(msg)
    return mlp("readout", params, h, 2)


# ======================================================================
# EquiformerV2 (eSCN-style)
# ======================================================================
@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 16
    cutoff: float = 8.0
    d_in: int = 16
    d_out: int = 1
    edge_chunks: int = 1
    node_shard: tuple = None
    edge_shard: tuple = None
    feat_shard: tuple = None

    @property
    def n_lm(self) -> int:
        return (self.l_max + 1) ** 2


def _m_blocks(l_max: int, m_max: int):
    """For each m in [-m_max, m_max]: flat (l,m) indices with l >= |m|."""
    ls, ms = lm_tables(l_max)
    blocks = []
    for m in range(-m_max, m_max + 1):
        idx = np.nonzero(ms == m)[0]
        blocks.append((m, idx))
    return blocks


def equiformer_param_shapes(cfg: EquiformerV2Config):
    sd = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    c = cfg.d_hidden
    out = {"embed_w": sd(cfg.d_in, c), "embed_b": sd(c)}
    blocks = _m_blocks(cfg.l_max, cfg.m_max)
    for i in range(cfg.n_layers):
        for m, idx in blocks:
            out[f"so2_{i}_m{m}"] = sd(len(idx), c, c)       # l-mix per m block
        out.update(_mlp_shapes(f"alpha{i}", (2 * c + cfg.n_rbf, c,
                                             cfg.n_heads), sd))
        out.update(_mlp_shapes(f"rad{i}", (cfg.n_rbf, c, c), sd))
        out[f"gate{i}_w"] = sd(c, c * (cfg.l_max + 1))
        out.update(_mlp_shapes(f"ffn{i}", (c, 2 * c, c), sd))
    out.update(_mlp_shapes("readout", (c, c, cfg.d_out), sd))
    return out


def equiformer_forward(cfg: EquiformerV2Config, params, batch):
    n = batch["features"].shape[0]
    pos = batch["positions"]
    edges = chunk_edges((batch["edge_src"], batch["edge_dst"]),
                        cfg.edge_chunks)
    ls, _ = lm_tables(cfg.l_max)
    l_of = jnp.asarray(ls)
    c, h_heads = cfg.d_hidden, cfg.n_heads
    blocks = _m_blocks(cfg.l_max, cfg.m_max)
    nc = cfg.edge_chunks

    def geom(src_c, dst_c):
        vec, dist = edge_geometry_chunk(pos, src_c, dst_c)
        rhat = vec / jnp.maximum(dist[:, None], 1e-6)
        sh = real_sph_harm(cfg.l_max, rhat)
        rbf = bessel_rbf(dist, cfg.n_rbf, cfg.cutoff) * \
            cosine_cutoff(dist, cfg.cutoff)[:, None]
        return sh, rbf

    # init irrep features: scalar channel from inputs
    h0 = mm(batch["features"], params["embed_w"]) + params["embed_b"]
    feats = constrain0(
        jnp.zeros((n, cfg.n_lm, c), jnp.float32).at[:, 0, :].set(h0),
        cfg.node_shard, cfg.feat_shard)

    for i in range(cfg.n_layers):
        # ---- pass 1 (cheap, invariant channels only): segment max + denom
        def alpha_logits(src_c, dst_c, rbf, _i=i):
            a_in = jnp.concatenate(
                [feats[src_c][:, 0], feats[dst_c][:, 0], rbf], -1)
            return mlp(f"alpha{_i}", params, a_in, 2)       # [e, H]

        def pass1(acc, ec, _i=i):
            src_c, dst_c = ec
            _, rbf = geom(src_c, dst_c)
            lg = alpha_logits(src_c, dst_c, rbf, _i)
            return jnp.maximum(
                acc, jax.ops.segment_max(lg, dst_c, num_segments=n))

        seg_max = edge_scan(pass1, jnp.full((n, h_heads), -1e30), edges, nc)
        seg_max = jnp.maximum(seg_max, -1e29)               # isolated nodes

        # ---- pass 2: eSCN messages weighted by unnormalized attention.
        # Messages live entirely in the |m| <= m_max subspace (29 of 49
        # components at L=6): gather/compute/scatter only that slice, in
        # bf16 — ~3.3x less all-gather volume at ogb scale, identical math
        # (components outside the slice were zero by construction).
        sel_sorted = np.unique(np.concatenate([idx for _, idx in blocks]))
        pos_of = {int(v): int(p) for p, v in enumerate(sel_sorted)}
        n_sel = len(sel_sorted)
        sel_d = jnp.asarray(sel_sorted)
        feats_msg = constrain0(
            feats[:, sel_d, :].astype(jnp.bfloat16),
            cfg.node_shard, cfg.feat_shard)

        def pass2(ec, _i=i):
            src_c, dst_c = ec
            sh, rbf = geom(src_c, dst_c)
            lg = alpha_logits(src_c, dst_c, rbf, _i)
            expl = jnp.exp(lg - seg_max[dst_c])             # [e, H]
            hs = feats_msg[src_c].astype(jnp.float32)       # [e, n_sel, C]
            msg = jnp.zeros((src_c.shape[0], n_sel, c), jnp.float32)
            for m, idx in blocks:
                w = params[f"so2_{_i}_m{m}"]                # [nl, C, C]
                rows = jnp.asarray([pos_of[int(v)] for v in idx])
                mixed = jnp.einsum("enc,ncd->end", hs[:, rows, :], w)
                msg = msg.at[:, rows, :].set(mixed)
            rad = mlp(f"rad{_i}", params, rbf, 2)           # [e, C]
            msg = msg * rad[:, None, :] * sh[:, sel_d, None]
            msg = msg.reshape(src_c.shape[0], n_sel, h_heads,
                              c // h_heads)
            msg = (msg * expl[:, None, :, None]).reshape(
                src_c.shape[0], n_sel, c)
            return (segment_sum(msg, dst_c, n),
                    segment_sum(expl, dst_c, n))

        num, den = sum_edge_scan(pass2, edges, nc, n,
                                 cfg.node_shard)            # [N, n_sel, C]
        den = jnp.repeat(den + 1e-9, c // h_heads, axis=-1)  # [N, C]
        feats = feats.at[:, sel_d, :].add(num / den[:, None, :])
        feats = constrain0(feats, cfg.node_shard, cfg.feat_shard)
        # ---- gated nonlinearity: scalars gate each l's components
        gate = jax.nn.sigmoid(
            mm(feats[:, 0], params[f"gate{i}_w"])).reshape(
                n, cfg.l_max + 1, c)
        feats = feats * gate[:, l_of, :]
        # ---- FFN on the invariant channel
        feats = feats.at[:, 0, :].add(mlp(f"ffn{i}", params, feats[:, 0], 2))

    return mlp("readout", params, feats[:, 0], 2)
