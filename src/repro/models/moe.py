"""Mixture-of-experts FFN: top-k routing with capacity, scatter dispatch,
batched expert SwiGLU, gather combine (GShard-style semantics, sort-free).

Dispatch builds a per-expert buffer ``[E, C, D]`` via scatter-add at unique
``expert * C + slot`` indices (slot = the token's running position within its
expert, from a cumulative sum over the one-hot routing matrix); tokens beyond
capacity are dropped, their combine weight zeroed — deterministic shapes, no
host-side sorting, all MXU/scatter ops.  Expert weights shard over the
``experts`` logical axis (expert parallelism); the token→expert buffer
transition is the all-to-all the dry-run should surface.
"""
from __future__ import annotations

import dataclasses
import operator
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import BF16


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    token_chunks: int = 1      # scan the MoE over token blocks (memory bound)
    # mesh axes for the dispatch buffers [E, C, D]: experts over 'model'
    # (expert parallelism) AND capacity over 'data' — without the capacity
    # constraint every data-row redundantly computes the full expert matmuls
    # (measured 16x expert FLOPs on granite prefill: the dot was
    # [E/16, C_full, D] on every device)
    experts_shard: tuple = None
    capacity_shard: tuple = None


def _constrain_experts(x, cfg):
    if cfg.experts_shard is None and cfg.capacity_shard is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(cfg.experts_shard, cfg.capacity_shard,
             *([None] * (x.ndim - 2))))


def capacity(num_tokens: int, cfg: MoEConfig) -> int:
    c = int(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    if c >= 512:
        return -(-c // 512) * 512   # large: keep 'data'-shardable
    return max(8, -(-c // 8) * 8)


def moe_ffn(x, router_w, w1, w3, w2, cfg: MoEConfig):
    """x: [T, D]; router_w: [D, E]; w1/w3: [E, D, F]; w2: [E, F, D].

    Returns (out [T, D] fp32, aux_loss scalar).  With ``token_chunks > 1``
    the dispatch/expert/combine pipeline runs under ``lax.scan`` over token
    blocks so the [E, C, D] buffers stay a fraction of the activation size
    (GShard-style microbatching inside the layer).
    """
    if cfg.token_chunks > 1:
        t, d = x.shape
        nc = cfg.token_chunks
        assert t % nc == 0, (t, nc)

        # remat each chunk: scan backward otherwise stacks every chunk's
        # dispatch buffers simultaneously (defeats the chunking)
        @jax.checkpoint
        def body(_, xc):
            out, aux = _moe_ffn_block(xc, router_w, w1, w3, w2, cfg)
            return None, (out, aux)

        _, (out, aux) = jax.lax.scan(body, None,
                                     x.reshape(nc, t // nc, d))
        return out.reshape(t, d), jnp.mean(aux)
    return _moe_ffn_block(x, router_w, w1, w3, w2, cfg)


def _moe_ffn_block(x, router_w, w1, w3, w2, cfg: MoEConfig):
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    c = capacity(t, cfg)

    logits = jnp.einsum("td,de->te", x.astype(BF16), router_w.astype(BF16),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                  # [T, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch): E * Σ_e fraction_e * prob_e
    frac = jnp.mean(jax.nn.one_hot(top_i[:, 0], e, dtype=jnp.float32), axis=0)
    aux = cfg.router_aux_weight * e * jnp.sum(frac * jnp.mean(probs, axis=0))

    # slot assignment: running count of earlier (t, k) pairs per expert.
    # log-depth associative scan — jnp.cumsum lowers to an O(n^2)
    # reduce-window on some backends, which both inflates cost_analysis and
    # is the wrong algorithm; the Blelloch scan is right everywhere.
    oh = jax.nn.one_hot(top_i.reshape(t * k), e, dtype=jnp.int32)  # [TK, E]
    slots = jax.lax.associative_scan(operator.add, oh, axis=0) - oh
    slot = jnp.sum(slots * oh, axis=-1)                            # [TK]
    keep = slot < c
    flat_expert = top_i.reshape(t * k)
    buf_idx = jnp.where(keep, flat_expert * c + slot, e * c)       # drop row

    # dispatch: scatter token activations into the expert buffers (bf16)
    x_rep = jnp.repeat(x.astype(BF16), k, axis=0)                  # [TK, D]
    buf = jnp.zeros((e * c + 1, d), BF16).at[buf_idx].add(x_rep)
    buf = _constrain_experts(buf[:-1].reshape(e, c, d), cfg)

    # batched expert SwiGLU
    h1 = jnp.einsum("ecd,edf->ecf", buf.astype(BF16), w1.astype(BF16),
                    preferred_element_type=jnp.float32)
    h3 = jnp.einsum("ecd,edf->ecf", buf.astype(BF16), w3.astype(BF16),
                    preferred_element_type=jnp.float32)
    h = jax.nn.silu(h1) * h3
    y = jnp.einsum("ecf,efd->ecd", h.astype(BF16), w2.astype(BF16),
                   preferred_element_type=jnp.float32)             # [E, C, D]
    y = _constrain_experts(y, cfg)

    # combine: gather each (t, k) row back, weight, sum over k
    y = y.astype(BF16)
    y_flat = jnp.concatenate([y.reshape(e * c, d),
                              jnp.zeros((1, d), BF16)], axis=0)
    gathered = y_flat[buf_idx]                                     # [TK, D]
    w = (top_p.reshape(t * k) * keep.astype(jnp.float32))[:, None]
    out = jnp.sum((gathered * w).reshape(t, k, d), axis=1)
    return out, aux
