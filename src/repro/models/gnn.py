"""GNN workloads: message passing via ``segment_sum`` over an edge index —
the TPU-native realization of SpMM-style aggregation (JAX has no CSR; the
scatter/gather regime per the kernel taxonomy §GNN).  The MXU-friendly
blocked one-hot variant lives in :mod:`repro.kernels.segment_matmul`.

**Edge chunking**: at `ogb_products` scale (62M directed edges) per-edge
intermediates (RBF bases, messages, MLP hiddens) would be 100s of GB.  Every
model here processes edges in ``cfg.edge_chunks`` blocks under ``lax.scan``
— per-edge tensors exist only at ``[E/chunks, ...]`` size, node-level
accumulators carry across chunks.  ``edge_chunks=1`` is the small-graph path.

Models here: SchNet (continuous-filter convolutions) and GraphCast
(encoder-processor-decoder MPNN).  Equivariant models (MACE, EquiformerV2)
are in :mod:`repro.models.equivariant`.

Uniform batch layout: ``features [N, F]``, ``positions [N, 3]``,
``edge_src [E]``, ``edge_dst [E]``, ``targets`` (+ optional ``graph_ids``,
``node_mask``).  E must be divisible by ``edge_chunks`` (input builders pad
with dummy-node edges).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from .layers import BF16, mm


# ------------------------------------------------------------------ helpers
def _constrain_e(x, cfg):
    """Chunk-major edge latents [nc, chunk, D]: shard the chunk dim."""
    if cfg.edge_shard is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(None, cfg.edge_shard, *([None] * (x.ndim - 2))))


def constrain0(x, axes, feat_axes=None):
    """Shard dim 0 (and optionally the last, feature dim) of ``x``."""
    if axes is None and feat_axes is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(axes, *([None] * (x.ndim - 2)), feat_axes))

def segment_sum(data, segment_ids, num_segments):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def mlp(params_prefix: str, params, x, n_layers: int, act=jax.nn.silu):
    for i in range(n_layers):
        x = mm(x, params[f"{params_prefix}_w{i}"]) + \
            params[f"{params_prefix}_b{i}"]
        if i < n_layers - 1:
            x = act(x)
    return x


def _mlp_shapes(prefix: str, dims, sd):
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"{prefix}_w{i}"] = sd(a, b)
        out[f"{prefix}_b{i}"] = sd(b)
    return out


def gaussian_rbf(dist, n_rbf: int, cutoff: float):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * jnp.square(dist[:, None] - centers[None, :]))


def cosine_cutoff(dist, cutoff: float):
    return jnp.where(dist < cutoff,
                     0.5 * (jnp.cos(math.pi * dist / cutoff) + 1.0), 0.0)


def chunk_edges(edge_arrays, n_chunks: int):
    """Normalize edge arrays to chunk-major [n_chunks, E/n_chunks, ...].

    Callers at scale pass them PRE-CHUNKED from the input pipeline: an
    in-jit reshape of a 256-way-sharded [E] array to [nc, chunk] makes
    GSPMD factorize the sharding across both dims (measured: per-chunk
    tensors only 4-way sharded on ogb_products).  1-D inputs (host tests)
    are reshaped here as a fallback."""
    def norm(x):
        if x.ndim >= 2 and x.shape[0] == n_chunks:
            return x
        return x.reshape((n_chunks, x.shape[0] // n_chunks) + x.shape[1:])
    return jax.tree.map(norm, edge_arrays)


def edge_scan(fn, accum_init, edge_arrays, n_chunks: int):
    """``accum' = fn(accum, chunk_of(edge_arrays))`` over edge chunks."""
    if n_chunks == 1:   # single-trip: inline (keeps probe HLO loop-free)
        return fn(accum_init, jax.tree.map(
            lambda x: x[0] if (x.ndim >= 2 and x.shape[0] == 1) else x,
            edge_arrays))
    xs = chunk_edges(edge_arrays, n_chunks)

    # remat per chunk: otherwise scan backward stacks every chunk's
    # per-edge intermediates (RBF/SH/messages) simultaneously
    @jax.checkpoint
    def body(acc, xc):
        return fn(acc, xc), None

    acc, _ = jax.lax.scan(body, accum_init, xs)
    return acc


def sum_edge_scan(fn, edge_arrays, n_chunks: int, num_nodes: int = None,
                  node_shard=None):   # edge_arrays: [E] or [nc, E/nc]
    """Σ over edge chunks of ``fn(chunk)`` — pure accumulation, so the
    custom-VJP :func:`repro.models.scan_utils.sum_scan` applies (backward
    replays chunks against one shared cotangent; no stacked carries).

    ``num_nodes``/``node_shard`` pin the sharding of backward cotangent
    accumulators whose leading dim is the node count (GSPMD otherwise
    replicates them through the while loop — measured 414 GiB/dev on
    equiformer/ogb)."""
    if n_chunks == 1:
        return fn(jax.tree.map(
            lambda x: x[0] if (x.ndim >= 2 and x.shape[0] == 1) else x,
            edge_arrays))
    from .scan_utils import sum_scan
    dc_fix = None
    if node_shard is not None and num_nodes is not None:
        def dc_fix(c, d):
            if hasattr(d, "shape") and d.ndim >= 1 and \
                    d.shape[0] == num_nodes:
                return constrain0(d, node_shard)
            return d
    return sum_scan(fn, chunk_edges(edge_arrays, n_chunks), dc_fix=dc_fix)


def edge_geometry_chunk(positions, src_c, dst_c):
    vec = positions[src_c] - positions[dst_c]
    dist = jnp.sqrt(jnp.sum(jnp.square(vec), -1) + 1e-12)
    return vec, dist


def pool_or_identity(out, batch):
    if "graph_ids" in batch:
        g = int(batch["num_graphs"])
        return segment_sum(out, batch["graph_ids"], g)
    return out


def gnn_loss(forward_fn, cfg, params, batch):
    out = forward_fn(cfg, params, batch)
    if "node_mask" in batch and "graph_ids" not in batch:
        m = batch["node_mask"][:, None]
        err = jnp.square(out - batch["targets"].astype(jnp.float32)) * m
        return jnp.sum(err) / (jnp.sum(m) * out.shape[-1] + 1e-9)
    out = pool_or_identity(out, batch)
    return jnp.mean(jnp.square(out.astype(jnp.float32) -
                               batch["targets"].astype(jnp.float32)))


# ======================================================================
# SchNet  [arXiv:1706.08566]
# ======================================================================
@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    d_in: int = 16
    d_out: int = 1
    edge_chunks: int = 1
    node_shard: tuple = None
    edge_shard: tuple = None
    feat_shard: tuple = None


def schnet_param_shapes(cfg: SchNetConfig):
    sd = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    d = cfg.d_hidden
    out = {"embed_w": sd(cfg.d_in, d), "embed_b": sd(d)}
    for i in range(cfg.n_interactions):
        out.update(_mlp_shapes(f"filter{i}", (cfg.n_rbf, d, d), sd))
        out.update({f"in{i}_w": sd(d, d),
                    f"out{i}_w0": sd(d, d), f"out{i}_b0": sd(d),
                    f"out{i}_w1": sd(d, d), f"out{i}_b1": sd(d)})
    out.update(_mlp_shapes("readout", (d, d // 2, cfg.d_out), sd))
    return out


def schnet_forward(cfg: SchNetConfig, params, batch):
    n = batch["features"].shape[0]
    pos = batch["positions"]
    h = constrain0(mm(batch["features"], params["embed_w"]) +
                   params["embed_b"], cfg.node_shard, cfg.feat_shard)
    edges = chunk_edges((batch["edge_src"], batch["edge_dst"]),
                        cfg.edge_chunks)
    for i in range(cfg.n_interactions):
        hw = mm(h, params[f"in{i}_w"])

        def chunk(ec, _i=i):
            src_c, dst_c = ec
            _, dist = edge_geometry_chunk(pos, src_c, dst_c)
            rbf = gaussian_rbf(dist, cfg.n_rbf, cfg.cutoff)
            w = mlp(f"filter{_i}", params, rbf, 2) * \
                cosine_cutoff(dist, cfg.cutoff)[:, None]
            return segment_sum(hw[src_c] * w, dst_c, n)

        agg = sum_edge_scan(chunk, edges, cfg.edge_chunks, n,
                            cfg.node_shard)
        h = constrain0(h + mlp(f"out{i}", params, agg, 2), cfg.node_shard,
                       cfg.feat_shard)
    return mlp("readout", params, h, 2)


# ======================================================================
# GraphCast processor  [arXiv:2212.12794]
# ======================================================================
@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227
    d_in: int = 227
    d_edge_in: int = 4
    edge_chunks: int = 1
    node_shard: tuple = None
    edge_shard: tuple = None
    feat_shard: tuple = None


def graphcast_param_shapes(cfg: GraphCastConfig):
    sd = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    d = cfg.d_hidden
    out = {}
    out.update(_mlp_shapes("enc_node", (cfg.d_in, d, d), sd))
    out.update(_mlp_shapes("enc_edge", (cfg.d_edge_in, d, d), sd))
    for i in range(cfg.n_layers):
        out.update(_mlp_shapes(f"edge{i}", (3 * d, d, d), sd))
        out.update(_mlp_shapes(f"node{i}", (2 * d, d, d), sd))
    out.update(_mlp_shapes("dec", (d, d, cfg.n_vars), sd))
    return out


def graphcast_forward(cfg: GraphCastConfig, params, batch):
    n = batch["features"].shape[0]
    pos = batch["positions"]
    nc = cfg.edge_chunks
    src, dst = chunk_edges((batch["edge_src"], batch["edge_dst"]), nc)
    d = cfg.d_hidden
    h = constrain0(mlp("enc_node", params, batch["features"], 2),
                   cfg.node_shard, cfg.feat_shard)

    # encoder: per-chunk edge geometry → edge latent e [E, D] (persistent)
    def enc_chunk(_, ec):
        src_c, dst_c = ec
        vec, dist = edge_geometry_chunk(pos, src_c, dst_c)
        ef = jnp.concatenate([vec, dist[:, None]], axis=-1)
        return None, mlp("enc_edge", params, ef, 2)

    if nc == 1:
        _, e1 = enc_chunk(None, (src[0], dst[0]))
        e = e1[None]
    else:
        _, e = jax.lax.scan(enc_chunk, None, (src, dst))
    e = _constrain_e(e, cfg)                    # [nc, chunk, D]

    for i in range(cfg.n_layers):
        def layer_chunk(acc, ec, _i=i):
            e_c, src_c, dst_c = ec
            upd = mlp(f"edge{_i}", params,
                      jnp.concatenate([e_c, h[src_c], h[dst_c]], -1), 2)
            e_new = e_c + upd
            return acc + segment_sum(e_new, dst_c, n), e_new

        def body(acc, xc, _i=i):
            return layer_chunk(acc, xc, _i)

        if nc == 1:
            agg, e1 = body(jnp.zeros((n, d), jnp.float32),
                           (e[0], src[0], dst[0]))
            e_chunks = e1[None]
        else:
            agg, e_chunks = jax.lax.scan(
                jax.checkpoint(body), jnp.zeros((n, d), jnp.float32),
                (e, src, dst))
        e = _constrain_e(e_chunks, cfg)
        h = constrain0(
            h + mlp(f"node{i}", params, jnp.concatenate([h, agg], -1), 2),
            cfg.node_shard, cfg.feat_shard)
    return mlp("dec", params, h, 2)
