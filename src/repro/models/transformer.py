"""Decoder-only LM family covering the five assigned architectures:

* glm4-9b     — dense, RoPE, GQA (2 KV heads)
* gemma2-9b   — dense, alternating local(4096)/global attention, logit
                soft-capping (attn 50, final 30), post-norms
* phi3-mini   — dense, RoPE, SwiGLU (kv == q heads)
* granite-moe — MoE 32e top-8
* arctic-480b — MoE 128e top-2 with a parallel dense-FFN residual branch

One config dataclass selects everything; the forward pass is a single
``lax.scan`` over stacked layer parameters (remat'd), attention is the
chunked online-softmax from :mod:`repro.models.layers`, the LM loss streams
over sequence chunks so full-vocab logits are never materialized.
Decode (serve) is an unrolled per-layer loop so local layers get *static*
sliding-window cache reads.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .layers import (BF16, apply_rope, chunked_attention, decode_attention,
                     mm, rms_norm, softcap)
from .moe import MoEConfig, moe_ffn
from .sharding import LM_RULES, resolve


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    rope_theta: float = 10000.0
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    window: Optional[int] = None      # sliding-window size for local layers
    local_global: bool = False        # alternate local/global (gemma2)
    use_post_norms: bool = False      # gemma2 post-attention/post-ffn norms
    moe: Optional[MoEConfig] = None
    dense_residual: bool = False      # arctic: dense FFN parallel to MoE
    norm_eps: float = 1e-6
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 512
    remat: bool = True
    # activation sharding (sequence parallelism): residual stream constrained
    # to P(batch_shard, seq_shard, None) between layers when set
    batch_shard: tuple = None
    seq_shard: tuple = None
    # probe mode: python-unrolled layer loop (XLA cost_analysis counts scan
    # bodies once; unrolled HLO measures true per-layer cost)
    unroll_layers: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 256) * 256

    def is_local(self, layer: int) -> bool:
        return self.local_global and layer % 2 == 0

    @property
    def local_flags(self) -> np.ndarray:
        return np.array([self.is_local(i) for i in range(self.n_layers)],
                        np.bool_)

    def param_count(self) -> int:
        shapes = jax.tree.leaves(param_shapes(self))
        return sum(int(np.prod(s.shape)) for s in shapes)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        total = self.param_count()
        if self.moe is None:
            return total
        e, k = self.moe.num_experts, self.moe.top_k
        expert = 3 * self.d_model * self.moe.d_ff_expert
        return total - self.n_layers * (e - k) * expert


# ------------------------------------------------------------------- params
def _layer_shapes(cfg: TransformerConfig) -> Dict[str, Any]:
    l, d = cfg.n_layers, cfg.d_model
    hq = cfg.n_heads * cfg.head_dim
    hkv = cfg.n_kv_heads * cfg.head_dim
    sd = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    out = {
        "attn_norm": sd(l, d),
        "wq": sd(l, d, hq), "wk": sd(l, d, hkv), "wv": sd(l, d, hkv),
        "wo": sd(l, hq, d),
        "ffn_norm": sd(l, d),
    }
    if cfg.use_post_norms:
        out["post_attn_norm"] = sd(l, d)
        out["post_ffn_norm"] = sd(l, d)
    if cfg.moe is not None:
        e, fe = cfg.moe.num_experts, cfg.moe.d_ff_expert
        out.update(router=sd(l, d, e), we1=sd(l, e, d, fe),
                   we3=sd(l, e, d, fe), we2=sd(l, e, fe, d))
    if cfg.moe is None or cfg.dense_residual:
        f = cfg.d_ff
        out.update(w1=sd(l, d, f), w3=sd(l, d, f), w2=sd(l, f, d))
    return out


def param_shapes(cfg: TransformerConfig) -> Dict[str, Any]:
    d, vp = cfg.d_model, cfg.vocab_padded
    sd = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    return {
        "embed": sd(vp, d),
        "layers": _layer_shapes(cfg),
        "final_norm": sd(d),
        "unembed": sd(d, vp),
    }


_LOGICAL = {
    "embed": ("vocab", "embed"),
    "unembed": ("embed", "vocab"),
    "final_norm": ("embed",),
    "attn_norm": ("layers", "embed"),
    "ffn_norm": ("layers", "embed"),
    "post_attn_norm": ("layers", "embed"),
    "post_ffn_norm": ("layers", "embed"),
    "wq": ("layers", "embed", "heads"),
    "wk": ("layers", "embed", "kv_heads"),
    "wv": ("layers", "embed", "kv_heads"),
    "wo": ("layers", "heads", "embed"),
    "w1": ("layers", "embed", "ff"),
    "w3": ("layers", "embed", "ff"),
    "w2": ("layers", "ff", "embed"),
    "router": ("layers", "embed", "experts"),
    "we1": ("layers", "experts", "embed", "expert_ff"),
    "we3": ("layers", "experts", "embed", "expert_ff"),
    "we2": ("layers", "experts", "expert_ff", "embed"),
}


def param_specs(cfg: TransformerConfig, mesh: Mesh,
                rules=None) -> Dict[str, Any]:
    rules = rules or LM_RULES
    shapes = param_shapes(cfg)

    def one(path, sds):
        name = path[-1]
        return resolve(mesh, rules, _LOGICAL[name], sds.shape)

    return jax.tree_util.tree_map_with_path(
        lambda p, s: one(tuple(k.key for k in p), s), shapes)


def init_params(cfg: TransformerConfig, rng) -> Dict[str, Any]:
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(rng, len(leaves))

    def one(key, sds):
        if sds.shape[-1:] and len(sds.shape) >= 2:
            scale = 1.0 / math.sqrt(sds.shape[-2])
        else:
            scale = 0.0   # norm scales start at 0 (rms_norm uses 1 + scale)
        if scale == 0.0:
            return jnp.zeros(sds.shape, sds.dtype)
        return jax.random.normal(key, sds.shape, sds.dtype) * scale

    return jax.tree.unflatten(treedef, [one(k, s)
                                        for k, s in zip(keys, leaves)])


# ------------------------------------------------------------------ forward
def _attention_block(cfg: TransformerConfig, p, x, positions, window_val):
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["attn_norm"])
    q = mm(h, p["wq"]).reshape(b, s, hq, dh)
    k = mm(h, p["wk"]).reshape(b, s, hkv, dh)
    v = mm(h, p["wv"]).reshape(b, s, hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    attn = chunked_attention(
        q, k, v, causal=True, window=window_val,
        logit_cap=cfg.attn_softcap,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = mm(attn.reshape(b, s, hq * dh).astype(BF16), p["wo"])
    if cfg.use_post_norms:
        out = rms_norm(out, p["post_attn_norm"])
    return out.astype(x.dtype), (k, v)


def _dense_ffn(p, h):
    g = jax.nn.silu(mm(h, p["w1"]))
    u = mm(h, p["w3"])
    return mm((g * u).astype(BF16), p["w2"])


def _ffn_block(cfg: TransformerConfig, p, x):
    b, s, d = x.shape
    h = rms_norm(x, p["ffn_norm"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        flat = h.reshape(b * s, d)
        out, aux = moe_ffn(flat, p["router"], p["we1"], p["we3"], p["we2"],
                           cfg.moe)
        out = out.reshape(b, s, d)
        if cfg.dense_residual:
            out = out + _dense_ffn(p, h)
    else:
        out = _dense_ffn(p, h)
    if cfg.use_post_norms:
        out = rms_norm(out, p["post_ffn_norm"])
    return out.astype(x.dtype), aux


def _constrain_act(cfg: TransformerConfig, x):
    if cfg.batch_shard is None and cfg.seq_shard is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(cfg.batch_shard, cfg.seq_shard, None))


def forward_trunk(cfg: TransformerConfig, params, tokens,
                  return_kv: bool = False):
    """Embed + all layers + final norm.  Returns (x [B,S,D] bf16, aux, kv)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(BF16)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), BF16)
    x = _constrain_act(cfg, x)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    local_flags = jnp.asarray(cfg.local_flags)
    big = jnp.int32(2 * s)
    win = jnp.int32(cfg.window or 0)

    def body(x, scanned):
        p, is_local = scanned
        window_val = jnp.where(is_local, win, big) if cfg.local_global \
            else (cfg.window if cfg.window else None)
        attn_out, kv = _attention_block(cfg, p, x, positions, window_val)
        x = _constrain_act(cfg, x + attn_out)
        ffn_out, aux = _ffn_block(cfg, p, x)
        x = _constrain_act(cfg, x + ffn_out)
        return x, (aux, kv if return_kv else None)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.unroll_layers:
        auxs, kvs_list = [], []
        for i in range(cfg.n_layers):
            p_i = jax.tree.map(lambda a: a[i], params["layers"])
            x, (aux, kv) = body(x, (p_i, local_flags[i]))
            auxs.append(aux)
            kvs_list.append(kv)
        auxs = jnp.stack(auxs)
        kvs = (jax.tree.map(lambda *xs: jnp.stack(xs), *kvs_list)
               if return_kv else None)
    else:
        x, (auxs, kvs) = jax.lax.scan(body, x,
                                      (params["layers"], local_flags))
    x = rms_norm(x, params["final_norm"])
    return x, jnp.sum(auxs), kvs


def lm_loss(cfg: TransformerConfig, params, tokens, targets):
    """Streaming cross-entropy over sequence chunks (no [B,S,V] logits;
    sum_scan keeps backward memory at one chunk's logits)."""
    x, aux, _ = forward_trunk(cfg, params, tokens)
    b, s, d = x.shape
    cs = min(cfg.loss_chunk, s)
    n_chunks = s // cs
    vp = cfg.vocab_padded
    vocab_mask = (jnp.arange(vp) < cfg.vocab)[None, None, :]

    def chunk(xc_tc):
        xc, tc = xc_tc
        logits = mm(xc, params["unembed"])                  # [B, cs, Vp] f32
        logits = softcap(logits, cfg.final_softcap)
        logits = jnp.where(vocab_mask, logits, -1e9)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - picked)

    if n_chunks == 1:
        total = chunk((x, targets))
    else:
        from .scan_utils import sum_scan
        xs = (x.reshape(b, n_chunks, cs, d).transpose(1, 0, 2, 3),
              targets.reshape(b, n_chunks, cs).transpose(1, 0, 2))
        total = sum_scan(chunk, xs)
    return total / (b * s) + aux


# ------------------------------------------------------------------ serving
def make_cache_shapes(cfg: TransformerConfig, batch: int, max_seq: int):
    sh = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(sh, BF16),
            "v": jax.ShapeDtypeStruct(sh, BF16)}


def prefill(cfg: TransformerConfig, params, tokens):
    """Full-sequence prefill: returns (last-position logits [B, Vp], cache)."""
    x, _, kvs = forward_trunk(cfg, params, tokens, return_kv=True)
    k, v = kvs                                    # [L, B, S, Hkv, Dh]
    logits = mm(x[:, -1], params["unembed"])
    logits = softcap(logits, cfg.final_softcap)
    return logits, {"k": k.astype(BF16), "v": v.astype(BF16)}


def decode_step(cfg: TransformerConfig, params, cache, tokens, position):
    """One decode step.  tokens: [B] int32; position: scalar int32 (the slot
    the new token occupies; cache holds ``position`` valid entries).

    Unrolled over layers so gemma2's local layers use static sliding-window
    cache reads (sub-quadratic decode at 512k context).
    """
    b = tokens.shape[0]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = jnp.take(params["embed"], tokens, axis=0).astype(BF16)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), BF16)
    pos = jnp.broadcast_to(position, (b, 1))
    k_cache, v_cache = cache["k"], cache["v"]

    for i in range(cfg.n_layers):
        p = jax.tree.map(lambda a: a[i], params["layers"])
        h = rms_norm(x, p["attn_norm"])
        q = mm(h, p["wq"]).reshape(b, 1, hq, dh)
        k = mm(h, p["wk"]).reshape(b, 1, hkv, dh)
        v = mm(h, p["wv"]).reshape(b, 1, hkv, dh)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k[None].astype(BF16), (i, 0, position, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v[None].astype(BF16), (i, 0, position, 0, 0))
        window = cfg.window if cfg.is_local(i) else None
        attn = decode_attention(
            q[:, 0], k_cache[i], v_cache[i], position,
            window=window, logit_cap=cfg.attn_softcap)
        attn_out = mm(attn.reshape(b, hq * dh).astype(BF16), p["wo"])
        if cfg.use_post_norms:
            attn_out = rms_norm(attn_out, p["post_attn_norm"])
        x = x + attn_out.astype(BF16)
        ffn_out, _ = _ffn_block(cfg, p, x[:, None])
        x = x + ffn_out[:, 0].astype(BF16)

    x = rms_norm(x, params["final_norm"])
    logits = mm(x, params["unembed"])
    logits = softcap(logits, cfg.final_softcap)
    return logits, {"k": k_cache, "v": v_cache}
