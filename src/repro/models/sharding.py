"""Logical-axis sharding rules.

Parameters and activations are annotated with *logical* axis names; a rule
set maps them to mesh axes.  :func:`resolve` drops a mapping when the
dimension size is not divisible by the mesh-axis extent (e.g. glm4's 2 KV
heads cannot shard over a 16-way model axis → replicated), so every
(arch × mesh) cell resolves to a valid PartitionSpec automatically.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

# default logical→mesh rules for LM training (Megatron-style TP + DP batch)
LM_RULES: Dict[str, Axes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,          # d_model replicated
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "expert_ff": None,
    "layers": None,
    "kv_seq": "model",      # decode KV cache sequence axis
    "cand": ("data", "model"),
    "nodes": ("pod", "data"),
    "edges": ("pod", "data"),
    "feat": "model",
    "table_vocab": "model",
}


def _mesh_size(mesh: Mesh, axes: Axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def resolve(mesh: Mesh, rules: Dict[str, Axes], logical: Sequence[Optional[str]],
            shape: Sequence[int]) -> P:
    """Build a PartitionSpec for ``shape`` from logical axis names.

    Mesh axes not present in the mesh (e.g. ``pod`` on the single-pod mesh)
    are silently dropped; non-divisible mappings fall back to replication.
    """
    assert len(logical) == len(shape), (logical, shape)
    spec = []
    for name, dim in zip(logical, shape):
        axes = rules.get(name) if name else None
        if axes is None:
            spec.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if a in mesh.shape)
        if not axes:
            spec.append(None)
            continue
        if dim % _mesh_size(mesh, axes) != 0:
            # try prefixes before giving up (e.g. ('data','model') -> ('data',))
            while axes and dim % _mesh_size(mesh, axes) != 0:
                axes = axes[:-1]
            spec.append(tuple(axes) if len(axes) > 1 else
                        (axes[0] if axes else None))
            continue
        spec.append(tuple(axes) if len(axes) > 1 else axes[0])
    return P(*spec)


def named(mesh: Mesh, rules: Dict[str, Axes], logical, shape) -> NamedSharding:
    return NamedSharding(mesh, resolve(mesh, rules, logical, shape))
