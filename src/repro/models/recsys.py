"""Wide & Deep recsys [arXiv:1606.07792].

The hot path is the sparse embedding lookup over large tables.  JAX has no
native EmbeddingBag — lookups are expressed as gathers over a stacked
per-field table ``[F, V, D]`` (vocab-sharded over the ``model`` axis; GSPMD
turns the gather into local-gather + mask + all-reduce, which *is* the
one-hot-matmul trick semantically).  The Pallas VMEM-tiled variant lives in
:mod:`repro.kernels.embedding_bag`, with multi-hot bags reduced via
``segment_sum``.

``retrieval_cand`` (score one query against 10^6 candidates) broadcasts the
user context and sweeps the item field — a batched-matmul scoring pass plus
a global top-k, reusing the paper's top-k result-set semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .layers import BF16, mm


@dataclasses.dataclass(frozen=True)
class WideDeepConfig:
    name: str
    n_sparse: int = 40
    n_dense: int = 13
    vocab_per_field: int = 1_000_000
    embed_dim: int = 32
    mlp_dims: tuple = (1024, 512, 256)
    item_field: int = 0           # field swept during retrieval scoring


def widedeep_param_shapes(cfg: WideDeepConfig):
    sd = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    f, v, d = cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim
    out = {
        "tables": sd(f, v, d),          # deep embeddings
        "wide": sd(f, v),               # wide (dim-1) embeddings
        "wide_dense_w": sd(cfg.n_dense, 1),
        "bias": sd(1),
    }
    dims = (f * d + cfg.n_dense,) + tuple(cfg.mlp_dims) + (1,)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"mlp_w{i}"] = sd(a, b)
        out[f"mlp_b{i}"] = sd(b)
    return out


def widedeep_param_specs(cfg: WideDeepConfig, mesh, rules=None):
    from .sharding import LM_RULES, resolve
    rules = rules or LM_RULES
    shapes = widedeep_param_shapes(cfg)
    logical = {
        "tables": (None, "table_vocab", None),
        "wide": (None, "table_vocab"),
        "wide_dense_w": (None, None),
        "bias": (None,),
    }
    out = {}
    for k, sds in shapes.items():
        if k.startswith("mlp_w"):
            lg = (None, "ff") if int(k[-1]) < len(cfg.mlp_dims) else (None, None)
        elif k.startswith("mlp_b"):
            lg = ("ff",) if int(k[-1]) < len(cfg.mlp_dims) else (None,)
        else:
            lg = logical[k]
        out[k] = resolve(mesh, rules, lg, sds.shape)
    return out


def _embed_lookup(params, sparse_ids):
    """sparse_ids [B, F] -> deep [B, F*D], wide_logit [B]."""
    f = sparse_ids.shape[1]
    fields = jnp.arange(f)[None, :]
    emb = params["tables"][fields, sparse_ids]           # [B, F, D]
    wide = params["wide"][fields, sparse_ids]            # [B, F]
    return emb.reshape(sparse_ids.shape[0], -1), jnp.sum(wide, axis=1)


def _deep_mlp(cfg, params, x):
    n_mlp = len(cfg.mlp_dims) + 1
    for i in range(n_mlp):
        x = mm(x, params[f"mlp_w{i}"]) + params[f"mlp_b{i}"]
        if i < n_mlp - 1:
            x = jax.nn.relu(x)
    return x[:, 0]


def widedeep_logits(cfg: WideDeepConfig, params, batch):
    deep_in, wide_logit = _embed_lookup(params, batch["sparse_ids"])
    deep_in = jnp.concatenate([deep_in, batch["dense"]], axis=-1)
    deep_logit = _deep_mlp(cfg, params, deep_in)
    wide_logit = wide_logit + \
        mm(batch["dense"], params["wide_dense_w"])[:, 0]
    return deep_logit + wide_logit + params["bias"][0]


def widedeep_loss(cfg: WideDeepConfig, params, batch):
    logits = widedeep_logits(cfg, params, batch)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))


def widedeep_serve(cfg: WideDeepConfig, params, batch):
    return jax.nn.sigmoid(widedeep_logits(cfg, params, batch))


def widedeep_retrieval(cfg: WideDeepConfig, params, dense, base_ids,
                       cand_ids, top_k: int = 128):
    """Score one user context against ``cand_ids`` item candidates.

    dense [1, n_dense]; base_ids [1, F]; cand_ids [C] → (scores, ids) top-k.
    Reference (paper-faithful top-k semantics): broadcast the context into a
    [C, F] batch and run the full model.  Kept as the baseline the optimized
    path is verified against (tests/test_models_smoke.py).
    """
    c = cand_ids.shape[0]
    ids = jnp.broadcast_to(base_ids, (c, cfg.n_sparse))
    ids = ids.at[:, cfg.item_field].set(cand_ids)
    batch = {"sparse_ids": ids,
             "dense": jnp.broadcast_to(dense, (c, cfg.n_dense))}
    scores = widedeep_logits(cfg, params, batch)
    return jax.lax.top_k(scores, top_k)


def widedeep_retrieval_fast(cfg: WideDeepConfig, params, dense, base_ids,
                            cand_ids, top_k: int = 128):
    """Factorized retrieval scoring: only ``item_field`` varies across the
    candidates, so the 39 constant fields' embeddings AND their contribution
    to the first MLP layer are computed ONCE and broadcast — per-candidate
    work shrinks to one embedding row + a [D_emb → mlp0] matmul slice
    (40x fewer lookups, ~25x fewer first-layer FLOPs).  Exactly equal to
    :func:`widedeep_retrieval` (tested)."""
    c = cand_ids.shape[0]
    f, d = cfg.n_sparse, cfg.embed_dim
    it = cfg.item_field

    # constant part: one row through embeddings + first-layer matmul
    deep_in_const, wide_const = _embed_lookup(params, base_ids)   # [1, F*D]
    wide_const = wide_const + mm(dense, params["wide_dense_w"])[:, 0]
    w0 = params["mlp_w0"]                       # [F*D + n_dense, mlp0]
    full_in = jnp.concatenate([deep_in_const, dense], axis=-1)
    h0_const = mm(full_in, w0) + params["mlp_b0"]                 # [1, mlp0]
    # subtract the base item field's contribution (it varies per candidate)
    w0_item = jax.lax.dynamic_slice_in_dim(w0, it * d, d, 0)      # [D, mlp0]
    item_base = deep_in_const[:, it * d:(it + 1) * d]
    h0_const = h0_const - mm(item_base, w0_item)
    wide_const = wide_const - params["wide"][it, base_ids[0, it]]

    # per-candidate part
    cand_emb = params["tables"][it, cand_ids]                     # [C, D]
    h0 = h0_const + mm(cand_emb, w0_item)                         # [C, mlp0]
    wide = wide_const + params["wide"][it, cand_ids]              # [C]
    x = jax.nn.relu(h0)
    n_mlp = len(cfg.mlp_dims) + 1
    for i in range(1, n_mlp):
        x = mm(x, params[f"mlp_w{i}"]) + params[f"mlp_b{i}"]
        if i < n_mlp - 1:
            x = jax.nn.relu(x)
    scores = x[:, 0] + wide + params["bias"][0]
    return jax.lax.top_k(scores, top_k)
