"""Chunked online-softmax attention with a flash-style custom VJP.

Forward saves only ``(out, logsumexp)`` — the [S, S] score matrix never
exists in either direction.  Backward recomputes per-(q-tile, kv-tile)
scores and accumulates dq/dk/dv, exactly the FlashAttention-2 recipe in
jnp (the Pallas kernel in ``repro/kernels/flash_attention.py`` is the
TPU-tiled forward; this is the jit path the models use — and without the
custom VJP, scan's saved carries cost ~17 GiB/device per layer at 4k).

Supports GQA (Hq = G·Hkv), causal masking, sliding windows (``window`` may
be a *traced* scalar — gemma2 alternates local/global inside one scan), and
gemma2's logit soft-capping (tanh rescale, differentiated exactly in bwd).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

BF16 = jnp.bfloat16
NEG = -1e30


def _scores(qc, kc, scale, cap):
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qc.astype(BF16), kc.astype(BF16),
                   preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    return s


def _mask(qp, kp, causal, win):
    m = jnp.ones((qp.shape[0], kp.shape[0]), bool)
    if causal:
        m &= qp[:, None] >= kp[None, :]
    m &= (qp[:, None] - kp[None, :]) < win
    return m


@functools.lru_cache(maxsize=64)
def _make_flash(causal: bool, cap: Optional[float], bq: int, bk: int,
                q_offset: int):

    def fwd_pass(q, k, v, win):
        """q: [B,Hkv,G,Tq,D]; k,v: [B,Hkv,Tk,D] → (out, lse)."""
        b, hkv, g, tq, d = q.shape
        tk = k.shape[2]
        nq, nk = tq // bq, tk // bk
        scale = 1.0 / (d ** 0.5)
        q_pos = q_offset + jnp.arange(tq)
        k_pos = jnp.arange(tk)

        def per_q(_, qi):
            qc = jax.lax.dynamic_slice_in_dim(q, qi * bq, bq, 3)
            qp = jax.lax.dynamic_slice_in_dim(q_pos, qi * bq, bq)

            def per_k(carry, ki):
                m, l, acc = carry
                kc = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, 2)
                vc = jax.lax.dynamic_slice_in_dim(v, ki * bk, bk, 2)
                kp = jax.lax.dynamic_slice_in_dim(k_pos, ki * bk, bk)
                s = _scores(qc, kc, scale, cap)
                msk = _mask(qp, kp, causal, win)
                s = jnp.where(msk, s, NEG)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + jnp.sum(p, axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bhgqk,bhkd->bhgqd", p.astype(BF16), vc.astype(BF16),
                    preferred_element_type=jnp.float32)
                return (m_new, l_new, acc_new), None

            init = (jnp.full((b, hkv, g, bq), NEG, jnp.float32),
                    jnp.zeros((b, hkv, g, bq), jnp.float32),
                    jnp.zeros((b, hkv, g, bq, d), jnp.float32))
            if nk == 1:
                (m, l, acc), _ = per_k(init, jnp.int32(0))
            else:
                (m, l, acc), _ = jax.lax.scan(per_k, init, jnp.arange(nk))
            out_c = acc / jnp.maximum(l, 1e-30)[..., None]
            lse_c = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)),
                              jnp.inf)
            return None, (out_c, lse_c)

        if nq == 1:
            _, (o, s) = per_q(None, jnp.int32(0))
            outs, lses = o[None], s[None]
        else:
            _, (outs, lses) = jax.lax.scan(per_q, None, jnp.arange(nq))
        # [nq, B,Hkv,G,bq,(D)] -> [B,Hkv,G,Tq,(D)]
        out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, tq, d)
        lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, hkv, g, tq)
        return out, lse

    @jax.custom_vjp
    def flash(q, k, v, win):
        return fwd_pass(q, k, v, win)[0]

    def flash_fwd(q, k, v, win):
        out, lse = fwd_pass(q, k, v, win)
        return out, (q, k, v, win, out, lse)

    def flash_bwd(res, dout):
        q, k, v, win, out, lse = res
        b, hkv, g, tq, d = q.shape
        tk = k.shape[2]
        nq, nk = tq // bq, tk // bk
        scale = 1.0 / (d ** 0.5)
        q_pos = q_offset + jnp.arange(tq)
        k_pos = jnp.arange(tk)
        delta = jnp.sum(dout * out, axis=-1)           # [B,Hkv,G,Tq]

        def per_q(carry, qi):
            dk, dv = carry
            sl = lambda x, ax: jax.lax.dynamic_slice_in_dim(
                x, qi * bq, bq, ax)
            qc, doc = sl(q, 3), sl(dout, 3)
            lse_c, del_c = sl(lse, 3), sl(delta, 3)
            qp = jax.lax.dynamic_slice_in_dim(q_pos, qi * bq, bq)

            def per_k(carry2, ki):
                dk, dv, dq_c = carry2
                kc = jax.lax.dynamic_slice_in_dim(k, ki * bk, bk, 2)
                vc = jax.lax.dynamic_slice_in_dim(v, ki * bk, bk, 2)
                kp = jax.lax.dynamic_slice_in_dim(k_pos, ki * bk, bk)
                s = _scores(qc, kc, scale, cap)
                msk = _mask(qp, kp, causal, win)
                p = jnp.where(msk & (lse_c[..., None] < jnp.inf),
                              jnp.exp(jnp.where(msk, s, NEG) -
                                      lse_c[..., None]), 0.0)
                dv_c = jnp.einsum("bhgqk,bhgqd->bhkd", p.astype(BF16),
                                  doc.astype(BF16),
                                  preferred_element_type=jnp.float32)
                dp = jnp.einsum("bhgqd,bhkd->bhgqk", doc.astype(BF16),
                                vc.astype(BF16),
                                preferred_element_type=jnp.float32)
                ds = p * (dp - del_c[..., None])       # d wrt capped score
                if cap is not None:
                    ds = ds * (1.0 - jnp.square(s / cap))
                ds = ds * scale
                dq_c = dq_c + jnp.einsum(
                    "bhgqk,bhkd->bhgqd", ds.astype(BF16), kc.astype(BF16),
                    preferred_element_type=jnp.float32)
                dk_c = jnp.einsum("bhgqk,bhgqd->bhkd", ds.astype(BF16),
                                  qc.astype(BF16),
                                  preferred_element_type=jnp.float32)
                dk = jax.lax.dynamic_update_slice_in_dim(
                    dk, jax.lax.dynamic_slice_in_dim(dk, ki * bk, bk, 2)
                    + dk_c, ki * bk, 2)
                dv = jax.lax.dynamic_update_slice_in_dim(
                    dv, jax.lax.dynamic_slice_in_dim(dv, ki * bk, bk, 2)
                    + dv_c, ki * bk, 2)
                return (dk, dv, dq_c), None

            dq0 = jnp.zeros((b, hkv, g, bq, d), jnp.float32)
            if nk == 1:
                (dk, dv, dq_c), _ = per_k((dk, dv, dq0), jnp.int32(0))
            else:
                (dk, dv, dq_c), _ = jax.lax.scan(per_k, (dk, dv, dq0),
                                                 jnp.arange(nk))
            return (dk, dv), dq_c

        dkv0 = (jnp.zeros((b, hkv, tk, d), jnp.float32),
                jnp.zeros((b, hkv, tk, d), jnp.float32))
        if nq == 1:
            (dk, dv), dq_c = per_q(dkv0, jnp.int32(0))
            dqs = dq_c[None]
        else:
            (dk, dv), dqs = jax.lax.scan(per_q, dkv0, jnp.arange(nq))
        dq = dqs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, g, tq, d)
        dwin = jnp.zeros((), jnp.float32)  # int cotangent (unused)
        import numpy as np
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
                np.zeros((), jax.dtypes.float0))

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    logit_cap: Optional[float] = None,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    q_offset: int = 0):
    """q: [B, Tq, Hq, D]; k, v: [B, Tk, Hkv, D] → [B, Tq, Hq, D] fp32."""
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    g = hq // hkv
    bq = min(q_chunk, tq)
    bk = min(kv_chunk, tk)
    assert tq % bq == 0 and tk % bk == 0
    win = jnp.asarray(window if window is not None else 2 * max(tq, tk),
                      jnp.int32)
    # GQA: repeat KV to full query heads BEFORE the kernel.  The
    # [hkv, g] head factorization breaks GSPMD head sharding (16-way
    # sharded hq cannot reshape to 8x2 → attention silently replicates;
    # measured 4x FLOPs on granite prefill).  The repeat keeps the head
    # axis intact/shardable; autodiff sums the group gradient for dk/dv.
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qh = q.transpose(0, 2, 1, 3).reshape(b, hq, 1, tq, d)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    fn = _make_flash(causal, logit_cap, bq, bk, q_offset)
    out = fn(qh, kh, vh, win)                          # [B,Hq,1,Tq,D]
    return out.reshape(b, hq, tq, d).transpose(0, 2, 1, 3)
