"""Span tracer with an in-memory ring buffer (DESIGN.md §16).

A *span* is a named timed phase (``engine.step``, ``vpq.refill``,
``checkpoint.commit`` ...).  :meth:`SpanTracer.span` returns a context
manager; on exit the completed span is recorded as a plain tuple
``(name, start_s, dur_s, tid)`` into a fixed-capacity ring buffer —
recording is an index increment plus a tuple store under a lock, no
allocation beyond the tuple, so tracing the per-step hot path stays
inside the §16 overhead budget.  When the ring wraps, the oldest spans
are dropped and :attr:`SpanTracer.dropped` counts them.

The buffer exports the Chrome trace-event JSON format (``ph: "X"``
complete events with microsecond ``ts``/``dur``), which loads directly
in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing`` — see
docs/OBSERVABILITY.md for the how-to.

:data:`NULL_TRACER` is the disabled twin: ``span()`` hands back one
shared pre-built no-op context manager, so a disabled tracer costs a
method call returning a constant.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import List, Optional


class _Span:
    """Context manager recording one completed span on ``__exit__``.
    Spans are recorded even when the body raises — a phase that died
    mid-flight is exactly what a trace should show."""

    __slots__ = ("_tracer", "name", "_t0")

    def __init__(self, tracer: "SpanTracer", name: str):
        self._tracer = tracer
        self.name = name
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter()
        self._tracer._record(self.name, self._t0, t1 - self._t0)


class SpanTracer:
    """Fixed-capacity ring buffer of completed spans."""

    def __init__(self, capacity: int = 1 << 16):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._ring: List[Optional[tuple]] = [None] * capacity
        self._next = 0              # monotone write index (never wraps)
        # epoch anchoring perf_counter spans to wall time for exports
        self._epoch_wall = time.time()
        self._epoch_perf = time.perf_counter()

    def span(self, name: str) -> _Span:
        return _Span(self, name)

    def _record(self, name: str, start: float, dur: float) -> None:
        tid = threading.get_ident()
        with self._lock:
            self._ring[self._next % self.capacity] = (name, start, dur,
                                                      tid)
            self._next += 1

    # ------------------------------------------------------------- reads
    @property
    def total_recorded(self) -> int:
        return self._next

    @property
    def dropped(self) -> int:
        return max(0, self._next - self.capacity)

    def spans(self) -> List[tuple]:
        """Retained spans, oldest first: ``(name, start_s, dur_s, tid)``
        with ``start_s`` on the ``time.perf_counter`` clock."""
        with self._lock:
            n = self._next
            if n <= self.capacity:
                out = self._ring[:n]
            else:
                i = n % self.capacity
                out = self._ring[i:] + self._ring[:i]
            return list(out)

    def clear(self) -> None:
        with self._lock:
            self._ring = [None] * self.capacity
            self._next = 0

    # ----------------------------------------------------------- exports
    def chrome_trace(self, pid: Optional[int] = None) -> dict:
        """Chrome trace-event JSON object (``{"traceEvents": [...]}``)
        with ``ph: "X"`` complete events, µs timestamps anchored to the
        epoch wall clock.  Loadable in Perfetto as-is."""
        if pid is None:
            pid = os.getpid()
        base = self._epoch_wall - self._epoch_perf
        events = []
        for name, start, dur, tid in self.spans():
            events.append({
                "name": name, "ph": "X", "pid": pid, "tid": tid,
                "ts": (base + start) * 1e6, "dur": dur * 1e6,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str,
                            pid: Optional[int] = None) -> str:
        """Write :meth:`chrome_trace` to ``path`` (JSON); returns the
        path for chaining."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(pid=pid), f)
        return path


# ------------------------------------------------------------------- no-op
class _NullSpan:
    """Shared do-nothing context manager — the disabled tracing path."""

    __slots__ = ()
    name = ""

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


NULL_SPAN = _NullSpan()


class NullTracer:
    capacity = 0
    total_recorded = 0
    dropped = 0

    def span(self, name: str) -> _NullSpan:
        return NULL_SPAN

    def spans(self) -> list:
        return []

    def clear(self) -> None:
        pass

    def chrome_trace(self, pid: Optional[int] = None) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str,
                            pid: Optional[int] = None) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(pid=pid), f)
        return path


NULL_TRACER = NullTracer()
