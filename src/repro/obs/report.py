"""Per-run time-breakdown reporting over recorded spans (DESIGN.md §16).

Aggregates a :class:`~repro.obs.trace.SpanTracer`'s retained spans into
per-phase totals and renders the breakdown table that BENCH rows cite —
the "why is this configuration fast" answer the tentpole promises.

Coverage is computed over :data:`TOP_LEVEL_SPANS` only: nested phases
(``engine.device_compute`` inside ``engine.step``, ``vpq.refill`` inside
``engine.refill``) would double-count the same wall time.  The §16
acceptance bar is top-level spans summing to ≥90% of measured wall time
on a complete instrumented run.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional

# spans that partition a run's wall time without nesting inside each
# other (checkpoint.commit runs on the writer thread and may overlap the
# stepping loop — acceptable for a coverage *floor*)
TOP_LEVEL_SPANS = ("engine.start", "engine.step", "engine.finalize",
                   "checkpoint.save", "checkpoint.commit")


def aggregate(spans: Iterable[tuple]) -> Dict[str, dict]:
    """Per-name totals over ``(name, start_s, dur_s, tid)`` tuples:
    ``{name: {count, total_s, max_s}}``, sorted by total descending."""
    agg: Dict[str, dict] = {}
    for name, _start, dur, _tid in spans:
        row = agg.get(name)
        if row is None:
            agg[name] = {"count": 1, "total_s": dur, "max_s": dur}
        else:
            row["count"] += 1
            row["total_s"] += dur
            row["max_s"] = max(row["max_s"], dur)
    return dict(sorted(agg.items(), key=lambda kv: -kv[1]["total_s"]))


def coverage(spans: Iterable[tuple], wall_s: float,
             top_level: Iterable[str] = TOP_LEVEL_SPANS) -> float:
    """Fraction of ``wall_s`` accounted for by top-level spans."""
    if wall_s <= 0:
        return 0.0
    names = frozenset(top_level)
    covered = sum(dur for name, _s, dur, _t in spans if name in names)
    return covered / wall_s


def format_table(spans: Iterable[tuple],
                 wall_s: Optional[float] = None) -> str:
    """Human-readable breakdown table; with ``wall_s`` each row gets a
    percent-of-wall column and a top-level coverage footer."""
    spans = list(spans)
    agg = aggregate(spans)
    lines = []
    if wall_s is not None:
        lines.append(f"{'phase':<28} {'count':>8} {'total s':>10} "
                     f"{'max ms':>9} {'% wall':>7}")
        for name, row in agg.items():
            lines.append(
                f"{name:<28} {row['count']:>8} {row['total_s']:>10.4f} "
                f"{1e3 * row['max_s']:>9.3f} "
                f"{100 * row['total_s'] / wall_s:>6.1f}%")
        lines.append(f"top-level span coverage: "
                     f"{100 * coverage(spans, wall_s):.1f}% of "
                     f"{wall_s:.3f}s wall")
    else:
        lines.append(f"{'phase':<28} {'count':>8} {'total s':>10} "
                     f"{'max ms':>9}")
        for name, row in agg.items():
            lines.append(
                f"{name:<28} {row['count']:>8} {row['total_s']:>10.4f} "
                f"{1e3 * row['max_s']:>9.3f}")
    return "\n".join(lines)
