"""Engine-wide observability: metrics + span tracing (DESIGN.md §16).

One :class:`Observability` object bundles a
:class:`~repro.obs.metrics.MetricsRegistry` and a
:class:`~repro.obs.trace.SpanTracer`.  Instrumented code takes an
``obs`` handle and uses it unconditionally::

    obs.counter("engine_steps_total").inc()
    with obs.span("engine.step"):
        ...

When observability is off the handle is :data:`NOOP` — a process-global
disabled instance whose registry/tracer are shared null objects, so the
instrumented line above costs two trivial method calls and nothing else.
Hot paths that must also skip ``time.perf_counter()`` calls guard on
``obs.enabled``.
"""
from __future__ import annotations

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               NULL_METRIC, NULL_REGISTRY, log_buckets)
from repro.obs.trace import NULL_SPAN, NULL_TRACER, SpanTracer
from repro.obs.report import TOP_LEVEL_SPANS, aggregate, coverage, \
    format_table


class Observability:
    """Metrics registry + span tracer behind one enable switch."""

    def __init__(self, enabled: bool = True, max_spans: int = 1 << 16):
        self.enabled = enabled
        if enabled:
            self.metrics = MetricsRegistry()
            self.tracer = SpanTracer(capacity=max_spans)
        else:
            self.metrics = NULL_REGISTRY
            self.tracer = NULL_TRACER

    # convenience pass-throughs so call sites read `obs.counter(...)`
    def counter(self, name: str, help: str = ""):
        return self.metrics.counter(name, help)

    def gauge(self, name: str, help: str = ""):
        return self.metrics.gauge(name, help)

    def histogram(self, name: str, help: str = "", buckets=None):
        return self.metrics.histogram(name, help, buckets=buckets)

    def span(self, name: str):
        return self.tracer.span(name)

    def snapshot(self) -> dict:
        """JSON-serializable state: all metrics + tracer occupancy."""
        return {
            "enabled": self.enabled,
            "metrics": self.metrics.snapshot(),
            "spans": {"recorded": self.tracer.total_recorded,
                      "dropped": self.tracer.dropped,
                      "capacity": self.tracer.capacity},
        }


#: process-global disabled instance — the default ``obs`` everywhere
NOOP = Observability(enabled=False)

__all__ = [
    "Observability", "NOOP",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "log_buckets",
    "NULL_METRIC", "NULL_REGISTRY",
    "SpanTracer", "NULL_TRACER", "NULL_SPAN",
    "TOP_LEVEL_SPANS", "aggregate", "coverage", "format_table",
]
