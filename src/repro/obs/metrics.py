"""Low-overhead metrics registry (DESIGN.md §16).

Three metric kinds, Prometheus-shaped:

* :class:`Counter` — monotone accumulator (``inc``); int or float amounts.
* :class:`Gauge` — last-write-wins instantaneous value (``set``/``inc``).
* :class:`Histogram` — fixed **log-spaced** buckets chosen at construction
  (:func:`log_buckets`), cumulative-``le`` semantics like the Prometheus
  text format.  Fixed buckets keep ``observe`` O(log buckets) with no
  allocation — the per-step hot path must stay in the microseconds.

All metrics are thread-safe (one lock per metric — the checkpoint writer
thread and the serve loop record concurrently).  The registry snapshots
to a plain dict (:meth:`MetricsRegistry.snapshot`) and to the Prometheus
text exposition format (:meth:`MetricsRegistry.to_prometheus`), both pure
reads.

The **null registry** (:data:`NULL_REGISTRY`) hands every caller one
shared do-nothing metric, so instrumented code holds real attribute
references whether observability is on or off and pays only a no-op
method call when off (DESIGN.md §16 overhead budget: <3% steps/sec with
metrics on, ~0% with the no-op — asserted by ``benchmarks/bench_obs.py``).
"""
from __future__ import annotations

import math
import threading
from bisect import bisect_left
from typing import Dict, Optional, Sequence


def log_buckets(lo: float, hi: float, per_decade: int = 3) -> tuple:
    """Fixed log-spaced histogram bounds from ``lo`` to ``hi`` inclusive,
    ``per_decade`` bounds per factor of 10.  ``log_buckets(1e-3, 1, 1)``
    is ``(1e-3, 1e-2, 1e-1, 1.0)``."""
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got lo={lo}, hi={hi}")
    if per_decade < 1:
        raise ValueError(f"per_decade must be >= 1, got {per_decade}")
    n = round(math.log10(hi / lo) * per_decade)
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


# default bounds for duration histograms: 1µs .. 100s, 3 buckets/decade —
# covers a kernel dispatch through a full checkpoint flush
DEFAULT_TIME_BUCKETS = log_buckets(1e-6, 100.0, per_decade=3)


def _fmt(v) -> str:
    """Prometheus sample-value formatting: integral floats print as
    integers so the text round-trips through ``float()`` losslessly."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class Counter:
    """Monotone counter; ``inc`` accepts int or float amounts (float for
    accumulated seconds, e.g. ``vpq_disk_read_seconds_total``)."""

    __slots__ = ("name", "help", "_lock", "_value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, amount=1) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up ({amount})")
        with self._lock:
            self._value += amount

    @property
    def value(self):
        return self._value

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self._value}


class Gauge:
    __slots__ = ("name", "help", "_lock", "_value")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0

    def set(self, value) -> None:
        with self._lock:
            self._value = value

    def inc(self, amount=1) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self):
        return self._value

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self._value}


class Histogram:
    """Fixed-bucket histogram.  ``bounds[i]`` is the inclusive upper edge
    of bucket ``i`` (Prometheus ``le``); one implicit ``+Inf`` bucket
    catches the rest.  ``observe`` is a bisect + two adds under a lock."""

    __slots__ = ("name", "help", "bounds", "_lock", "_counts", "_sum",
                 "_count")
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.help = help
        bounds = tuple(buckets) if buckets is not None \
            else DEFAULT_TIME_BUCKETS
        if any(nxt <= cur for nxt, cur in zip(bounds[1:], bounds)):
            raise ValueError(f"{name}: bucket bounds must be strictly "
                             f"increasing, got {bounds}")
        self.bounds = bounds
        self._lock = threading.Lock()
        self._counts = [0] * (len(bounds) + 1)      # last slot = +Inf
        self._sum = 0.0
        self._count = 0

    def observe(self, value) -> None:
        i = bisect_left(self.bounds, value)         # first bound >= value
        with self._lock:
            self._counts[i] += 1
            self._sum += value
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def snapshot(self) -> dict:
        with self._lock:
            return {"type": self.kind, "sum": self._sum,
                    "count": self._count, "bounds": list(self.bounds),
                    "counts": list(self._counts)}


class MetricsRegistry:
    """Name -> metric, get-or-create.  Callers resolve their handles once
    (constructor time) and hit the metric objects directly on the hot
    path — the registry lock is never taken per step."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get_or_create(self, cls, name: str, help: str, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kwargs)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> list:
        with self._lock:
            return sorted(self._metrics)

    # ------------------------------------------------------------ snapshots
    def snapshot(self) -> Dict[str, dict]:
        """Plain-dict view of every metric (JSON-serializable)."""
        with self._lock:
            metrics = list(self._metrics.values())
        return {m.name: dict(m.snapshot(), help=m.help) for m in metrics}

    def to_prometheus(self) -> str:
        """Prometheus/OpenMetrics text exposition of the registry."""
        with self._lock:
            metrics = [self._metrics[n] for n in sorted(self._metrics)]
        lines = []
        for m in metrics:
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            if m.kind == "histogram":
                snap = m.snapshot()
                cum = 0
                for bound, c in zip(snap["bounds"], snap["counts"]):
                    cum += c
                    lines.append(
                        f'{m.name}_bucket{{le="{_fmt(bound)}"}} {cum}')
                lines.append(
                    f'{m.name}_bucket{{le="+Inf"}} {snap["count"]}')
                lines.append(f"{m.name}_sum {_fmt(snap['sum'])}")
                lines.append(f"{m.name}_count {snap['count']}")
            else:
                lines.append(f"{m.name} {_fmt(m.value)}")
        return "\n".join(lines) + ("\n" if lines else "")


# ------------------------------------------------------------------- no-op
class _NullMetric:
    """One shared instance stands in for every metric when observability
    is off: same call surface, no state, no locks."""

    __slots__ = ()
    kind = "null"
    name = ""
    help = ""
    value = 0
    count = 0
    sum = 0.0

    def inc(self, amount=1) -> None:
        pass

    def set(self, value) -> None:
        pass

    def observe(self, value) -> None:
        pass

    def snapshot(self) -> dict:
        return {}


NULL_METRIC = _NullMetric()


class NullRegistry:
    """Registry that hands out :data:`NULL_METRIC` for everything."""

    def counter(self, name: str, help: str = ""):
        return NULL_METRIC

    def gauge(self, name: str, help: str = ""):
        return NULL_METRIC

    def histogram(self, name: str, help: str = "", buckets=None):
        return NULL_METRIC

    def get(self, name: str):
        return None

    def names(self) -> list:
        return []

    def snapshot(self) -> dict:
        return {}

    def to_prometheus(self) -> str:
        return ""


NULL_REGISTRY = NullRegistry()
