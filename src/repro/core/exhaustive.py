"""Baselines and oracles.

* :func:`brute_force_max_clique` / :func:`brute_force_cliques` — exact host
  oracles for tests.
* :class:`ArabesqueStyleClique` — the paper's comparison system, reproduced
  algorithmically: level-synchronous **exhaustive expansion** of connected
  subgraphs followed by **post-filtering** of non-cliques, no prioritization,
  no pruning (paper §2.2 / Fig. 2: creates s10, s11, s12 then discards them).
  Reports the paper's machine-independent cost metric — the number of
  candidate subgraphs created.
* :func:`nuri_np_clique_candidates` — "Nuri-NP": targeted expansion only
  (never creates non-cliques) but FIFO order and no pruning.
* :func:`brute_force_iso` / :func:`pattern_support_oracle` — oracles for
  subgraph isomorphism and min-image pattern support.
"""
from __future__ import annotations

import itertools
from collections import deque
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .graph import GraphStore


# --------------------------------------------------------------------- clique
def brute_force_max_clique(graph: GraphStore) -> Tuple[int, List[int]]:
    """Exact maximum clique by recursive candidate intersection (host)."""
    neigh = [set(map(int, graph.neighbors(v))) for v in range(graph.n)]
    best_size, best = 0, []

    def rec(cur: List[int], cand: Set[int]):
        nonlocal best_size, best
        if len(cur) > best_size:
            best_size, best = len(cur), list(cur)
        if len(cur) + len(cand) <= best_size:
            return
        for v in sorted(cand):
            rec(cur + [v], {u for u in cand if u > v and u in neigh[v]})

    rec([], set(range(graph.n)))
    return best_size, sorted(best)


def brute_force_cliques(graph: GraphStore, max_size: int) -> List[Tuple[int, ...]]:
    """All cliques up to ``max_size`` (host, for small test graphs)."""
    neigh = [set(map(int, graph.neighbors(v))) for v in range(graph.n)]
    out = []

    def rec(cur: List[int], cand: Set[int]):
        out.append(tuple(cur))
        if len(cur) == max_size:
            return
        for v in sorted(cand):
            rec(cur + [v], {u for u in cand if u > v and u in neigh[v]})

    for v in range(graph.n):
        rec([v], {u for u in neigh[v] if u > v})
    return out


class ArabesqueStyleClique:
    """Arabesque-style exhaustive expansion + post-filter for clique discovery.

    Level-synchronous: all size-ℓ subgraphs are produced before any size-ℓ+1
    subgraph (no prioritized expansion), every connected expansion is created
    then filtered (no targeted expansion), nothing is pruned (no top-k bound).
    """

    def __init__(self, graph: GraphStore, max_candidates: int = 2_000_000):
        self.g = graph
        self.neigh = [set(map(int, graph.neighbors(v)))
                      for v in range(graph.n)]
        self.max_candidates = max_candidates

    def run(self) -> dict:
        candidates = 0
        level: Set[Tuple[int, ...]] = {(v,) for v in range(self.g.n)}
        candidates += len(level)
        best_size, best = 1, next(iter(level)) if level else ()
        completed = True
        while level:
            nxt: Set[Tuple[int, ...]] = set()
            for sub in level:
                members = set(sub)
                frontier = set().union(*(self.neigh[v] for v in sub)) - members
                for u in frontier:
                    cand = tuple(sorted(members | {u}))
                    if cand in nxt:
                        continue
                    candidates += 1           # created BEFORE filtering
                    if candidates > self.max_candidates:
                        completed = False
                        break
                    # post-filter: keep only cliques
                    if all(b in self.neigh[a]
                           for a, b in itertools.combinations(cand, 2)):
                        nxt.add(cand)
                if not completed:
                    break
            if not completed:
                break
            if nxt:
                best_size = len(next(iter(nxt)))
                best = max(nxt)
            level = nxt
        return dict(candidates=candidates, max_clique_size=best_size,
                    clique=sorted(best), completed=completed)


def nuri_np_clique_candidates(graph: GraphStore,
                              max_candidates: int = 5_000_000) -> dict:
    """Nuri-NP: targeted expansion (cliques only), FIFO order, no pruning."""
    neigh = [set(map(int, graph.neighbors(v))) for v in range(graph.n)]
    q = deque()
    for v in range(graph.n):
        q.append((frozenset([v]), frozenset(u for u in neigh[v] if u > v)))
    candidates = len(q)
    best_size = 1
    completed = True
    while q:
        members, cand = q.popleft()
        best_size = max(best_size, len(members))
        for v in sorted(cand):
            child_cand = frozenset(
                u for u in cand if u > v and u in neigh[v])
            candidates += 1
            if candidates > max_candidates:
                completed = False
                q.clear()
                break
            q.append((members | {v}, child_cand))
    return dict(candidates=candidates, max_clique_size=best_size,
                completed=completed)


# ------------------------------------------------------------------------ iso
def brute_force_iso(graph: GraphStore, q_edges: List[Tuple[int, int]],
                    q_labels: List[int], induced: bool = True,
                    k: int = 1,
                    predicate=None) -> List[Tuple[int, Tuple[int, ...]]]:
    """Top-k induced subgraph isomorphisms by total degree (host oracle).

    ``predicate`` (a :class:`repro.core.labels.LabelPredicate`) applies
    the label-constrained semantics of DESIGN.md §12: per-query-vertex
    label classes (``q_any_of``), a global allowed-vertex set
    (``vertex_any_of``), and adjacency restricted to allowed edge types
    (``edge_any_of``) — scores remain full-graph degree sums.
    """
    nq = len(q_labels)
    q_adj = [[False] * nq for _ in range(nq)]
    for a, b in q_edges:
        q_adj[a][b] = q_adj[b][a] = True
    deg = graph.degrees
    labels = graph.labels
    if predicate is not None and labels is None and (
            predicate.vertex_any_of is not None
            or predicate.q_any_of is not None):
        raise ValueError(
            "label predicate requires a vertex-labeled graph")
    classes = [
        set(predicate.q_any_of[j]) if predicate is not None
        and predicate.q_any_of is not None else {q_labels[j]}
        for j in range(nq)]
    allowed = (set(predicate.vertex_any_of)
               if predicate is not None
               and predicate.vertex_any_of is not None else None)
    if predicate is not None and predicate.edge_any_of is not None:
        eadj = predicate.adjacency(graph)

        def has_edge(u, v):
            return bool((int(eadj[u, v // 32]) >> (v % 32)) & 1)
    else:
        has_edge = graph.has_edge
    results = []

    def rec(mapping: List[int]):
        d = len(mapping)
        if d == nq:
            score = int(sum(deg[v] for v in mapping))
            results.append((score, tuple(mapping)))
            return
        for v in range(graph.n):
            if v in mapping:
                continue
            if labels is not None and int(labels[v]) not in classes[d]:
                continue
            if allowed is not None and int(labels[v]) not in allowed:
                continue
            ok = True
            for i in range(d):
                has = has_edge(mapping[i], v)
                if q_adj[i][d] != has and (induced or q_adj[i][d]):
                    ok = False
                    break
            if ok:
                rec(mapping + [v])

    rec([])
    results.sort(key=lambda t: (-t[0], t[1]))
    return results[:k]


# -------------------------------------------------------------------- pattern
def pattern_support_oracle(graph: GraphStore,
                           p_edges: List[Tuple[int, int]],
                           p_labels: List[int]) -> int:
    """Minimum image-based support [5] of a pattern (non-induced embeddings)."""
    nq = len(p_labels)
    embeddings = _all_embeddings(graph, p_edges, p_labels)
    if not embeddings:
        return 0
    images = [set() for _ in range(nq)]
    for emb in embeddings:
        for j, v in enumerate(emb):
            images[j].add(v)
    return min(len(s) for s in images)


def _all_embeddings(graph: GraphStore, p_edges, p_labels):
    nq = len(p_labels)
    q_adj = [[False] * nq for _ in range(nq)]
    for a, b in p_edges:
        q_adj[a][b] = q_adj[b][a] = True
    labels = graph.labels
    out = []

    def rec(mapping: List[int]):
        d = len(mapping)
        if d == nq:
            out.append(tuple(mapping))
            return
        for v in range(graph.n):
            if v in mapping:
                continue
            if labels is not None and int(labels[v]) != p_labels[d]:
                continue
            ok = all(not q_adj[i][d] or graph.has_edge(mapping[i], v)
                     for i in range(d))
            if ok:
                rec(mapping + [v])

    rec([])
    return out
