"""Maximum-clique discovery on the engine (paper §3.2 / §4.1, CP bound [7]).

State layout (``S = 2W + 2`` int32 words, W = bitset words):

* ``[0:W)``      — V bitset (clique members),
* ``[W:2W)``     — P bitset (candidate vertices that keep it a clique,
  restricted to ids greater than the last added vertex — the paper's
  duplicate-avoidance rule, cf. Fig. 2: v1 is not re-added to s2),
* ``[2W]``       — ``|V|`` (clique size),
* ``[2W+1]``     — ``|P|``.

User functions (paper Table 1 → here):

* ``expandable(s, v)``  = ``v ∈ P_s``                      (targeted expansion)
* ``priority(s)``       = lexicographic ``(|V_s|, |P_s|)`` → ``|V|·(N+1)+|P|``
* ``relevant(s)``       = always true (only cliques are ever created)
* ``dominated(s, s')``  = ``|V_s| + |P_s| < |V_{s'}|``     (CP bound)

The child-scoring hot loop — ``popcount(P ∩ N(v) ∩ {u > v})`` for the whole
``[B, N]`` grid — is the compute kernel of the paper's system; it runs either
as pure jnp (reference) or via the Pallas kernel
:mod:`repro.kernels.frontier_expand` (``use_pallas=True``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from . import bitset
from .api import NEG, SubgraphComputation
from .graph import GraphStore


def make_clique_computation(graph: GraphStore,
                            use_pallas: bool = False,
                            interpret: Optional[bool] = None
                            ) -> SubgraphComputation:
    """``use_pallas`` selects the Pallas kernel for child scoring;
    ``interpret=None`` auto-detects the backend (DESIGN.md §10).  Both
    paths are byte-identical (tests/test_kernels.py parity suite)."""
    n = graph.n
    w = bitset.num_words(n)
    assert (n + 1) ** 2 < 2 ** 31, "int32 priority keys require N <= ~46k"
    S = 2 * w + 2

    adj = jnp.asarray(graph.adj_bits)                      # [N, W] uint32
    gt = jnp.asarray(bitset.lt_mask_table(n))              # [N, W] uint32
    ext_mask = adj & gt                                    # N(v) ∩ {u > v}

    if use_pallas:
        from repro.kernels import ops as kops

    def _unpack(states):
        v_bits = bitset.to_u32(states[..., :w])
        p_bits = bitset.to_u32(states[..., w:2 * w])
        size = states[..., 2 * w]
        pcount = states[..., 2 * w + 1]
        return v_bits, p_bits, size, pcount

    def _pack(v_bits, p_bits, size):
        pcount = bitset.popcount(p_bits)
        return jnp.concatenate([
            bitset.to_i32(v_bits), bitset.to_i32(p_bits),
            size[..., None], pcount[..., None]], axis=-1)

    # ------------------------------------------------------------ callbacks
    def init_frontier():
        # unit cliques {v} with P = N(v) ∩ {u > v}  (canonical seeds)
        v_bits = jnp.asarray(np.stack(
            [bitset.from_indices([v], n) for v in range(n)]))
        p_bits = ext_mask
        size = jnp.ones((n,), jnp.int32)
        states = _pack(v_bits, p_bits, size)
        pcount = states[:, 2 * w + 1]
        prio = size * (n + 1) + pcount
        ub = size + pcount
        return states, prio, ub

    def score_children(states):
        _, p_bits, size, _ = _unpack(states)
        if use_pallas:
            counts = kops.frontier_expand(p_bits, ext_mask,
                                          interpret=interpret)  # [B, N]
        else:
            inter = p_bits[:, None, :] & ext_mask[None, :, :]
            counts = bitset.popcount(inter, axis=-1)         # [B, N]
        in_p = bitset.to_bool(p_bits, n)                     # expandable
        child_prio = jnp.where(in_p, (size[:, None] + 1) * (n + 1) + counts,
                               NEG)
        child_ub = jnp.where(in_p, size[:, None] + 1 + counts, NEG)
        return child_prio, child_ub

    def materialize(states, actions):
        v_bits, p_bits, size, _ = _unpack(states)
        new_v = bitset.set_bit(v_bits, actions)
        new_p = p_bits & ext_mask[actions]
        return _pack(new_v, new_p, size + 1)

    def result_key(states):
        return states[:, 2 * w]          # clique size; always relevant

    def upper_bound(states):
        return states[:, 2 * w] + states[:, 2 * w + 1]

    def describe(state_row: np.ndarray) -> list:
        v_bits = np.asarray(state_row[:w]).view(np.uint32)
        return sorted(int(i) for i in
                      np.nonzero(np.asarray(
                          bitset.to_bool(jnp.asarray(v_bits), n)))[0])

    return SubgraphComputation(
        name="clique", state_width=S, num_actions=n,
        init_frontier=init_frontier, score_children=score_children,
        materialize=materialize, result_key=result_key,
        upper_bound=upper_bound, describe=describe)
