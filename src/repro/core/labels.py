"""Label predicates over attributed data graphs (DESIGN.md §12).

A :class:`LabelPredicate` constrains which vertices and edges of a labeled
:class:`~repro.core.graph.GraphStore` may participate in a discovery
query — the label-constrained workloads of query-driven subgraph systems
(Dasgupta & Gupta, arXiv:2102.09120).  Three independent components, all
optional:

* ``vertex_any_of`` — a set of allowed vertex labels; every matched data
  vertex must carry one of them (iso and pattern mining);
* ``q_any_of`` — per-query-vertex label *classes* (iso only): query
  vertex ``j`` may map to any data vertex whose label is in class ``j``,
  generalizing the exact ``q_labels`` match;
* ``edge_any_of`` — a set of allowed edge types; discovery runs on the
  spanning subgraph containing only edges of those types (requires a
  graph built with ``edge_labels``).

The predicate compiles to packed bitsets compatible with
:mod:`repro.core.bitset` — an allowed-vertex bitset ``[W]`` and a
type-restricted adjacency ``[N, W]`` — which is what lets the per-row
``mask`` argument of the masked-intersection kernel absorb it at no extra
pass (predicate pushdown, DESIGN.md §12).  The same object canonicalizes
to a JSON-stable dict for the service result-cache key.

Validation raises plain :class:`ValueError`; the service layer re-raises
it as ``ValidationError`` at request-submit time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

from . import bitset
from .graph import GraphStore

#: computation constructors accept one of these two placement modes:
#: ``pushdown`` folds the predicate into the kernel-path constraint masks
#: (and tightens the priority index); ``post`` materializes the
#: unconstrained candidates and filters them afterwards — the host-side
#: filtering baseline that ``benchmarks/bench_labeled.py`` measures
#: pushdown against.  Both return byte-identical complete-run top-k
#: (DESIGN.md §12).
LABEL_FILTERS = ("pushdown", "post")

_SPEC_FIELDS = ("vertex_any_of", "q_any_of", "edge_any_of")


def _int_tuple(name: str, value) -> Tuple[int, ...]:
    try:
        out = tuple(int(x) for x in value)
    except (TypeError, ValueError) as e:
        raise ValueError(f"label_predicate.{name}: expected a list of "
                         f"ints, got {value!r}") from e
    if not out:
        raise ValueError(f"label_predicate.{name}: must be non-empty "
                         f"when present (omit the field for no constraint)")
    if any(x < 0 for x in out):
        raise ValueError(f"label_predicate.{name}: labels must be >= 0, "
                         f"got {sorted(out)}")
    return tuple(sorted(set(out)))


@dataclasses.dataclass(frozen=True)
class LabelPredicate:
    """A validated, canonicalized label constraint (all components optional).

    Construct via :meth:`from_spec`, which accepts a JSON-decoded dict (the
    ``label_predicate`` request field), an existing predicate, or ``None``
    (returns ``None``).  Fields are canonical: sorted, deduplicated tuples.
    """

    vertex_any_of: Optional[Tuple[int, ...]] = None
    q_any_of: Optional[Tuple[Tuple[int, ...], ...]] = None
    edge_any_of: Optional[Tuple[int, ...]] = None

    # ------------------------------------------------------------- building
    @staticmethod
    def from_spec(spec) -> Optional["LabelPredicate"]:
        if spec is None:
            return None
        if isinstance(spec, LabelPredicate):
            return spec
        if not isinstance(spec, dict):
            raise ValueError(
                f"label_predicate must be an object with any of "
                f"{_SPEC_FIELDS}, got {type(spec).__name__}")
        unknown = set(spec) - set(_SPEC_FIELDS)
        if unknown:
            raise ValueError(
                f"unknown label_predicate fields: {sorted(unknown)} "
                f"(known: {_SPEC_FIELDS})")
        v = spec.get("vertex_any_of")
        q = spec.get("q_any_of")
        e = spec.get("edge_any_of")
        if v is not None:
            v = _int_tuple("vertex_any_of", v)
        if e is not None:
            e = _int_tuple("edge_any_of", e)
        if q is not None:
            try:
                q = tuple(_int_tuple(f"q_any_of[{j}]", cls)
                          for j, cls in enumerate(q))
            except TypeError as err:
                raise ValueError(
                    "label_predicate.q_any_of: expected a list of label "
                    "lists, one per query vertex") from err
            if not q:
                raise ValueError(
                    "label_predicate.q_any_of: must be non-empty when "
                    "present")
        pred = LabelPredicate(vertex_any_of=v, q_any_of=q, edge_any_of=e)
        if pred.is_trivial:
            return None
        return pred

    @property
    def is_trivial(self) -> bool:
        return (self.vertex_any_of is None and self.q_any_of is None
                and self.edge_any_of is None)

    # ----------------------------------------------------------- validation
    def validate(self, graph: GraphStore, workload: str,
                 nq: Optional[int] = None) -> None:
        """Check the predicate against a graph + workload; raises ValueError."""
        if graph.labels is None:
            raise ValueError(
                f"label_predicate requires a vertex-labeled graph "
                f"({workload} on an unlabeled graph)")
        n_labels = graph.n_labels
        if self.vertex_any_of is not None and \
                max(self.vertex_any_of) >= n_labels:
            raise ValueError(
                f"label_predicate.vertex_any_of: label "
                f"{max(self.vertex_any_of)} out of range for a graph "
                f"with {n_labels} vertex labels")
        if self.q_any_of is not None:
            if workload != "iso":
                raise ValueError(
                    "label_predicate.q_any_of applies to iso only "
                    f"(got workload {workload!r})")
            if nq is not None and len(self.q_any_of) != nq:
                raise ValueError(
                    f"label_predicate.q_any_of has {len(self.q_any_of)} "
                    f"classes for {nq} query vertices")
            bad = max(max(cls) for cls in self.q_any_of)
            if bad >= n_labels:
                raise ValueError(
                    f"label_predicate.q_any_of: label {bad} out of range "
                    f"for a graph with {n_labels} vertex labels")
        if self.edge_any_of is not None:
            if graph.edge_labels is None:
                raise ValueError(
                    "label_predicate.edge_any_of requires a graph built "
                    "with edge_labels")
            if max(self.edge_any_of) >= graph.n_edge_labels:
                raise ValueError(
                    f"label_predicate.edge_any_of: type "
                    f"{max(self.edge_any_of)} out of range for a graph "
                    f"with {graph.n_edge_labels} edge types")

    # -------------------------------------------------------- canonical form
    def canonical(self) -> Dict[str, Any]:
        """JSON-stable dict for the service result-cache key."""
        out: Dict[str, Any] = {}
        if self.vertex_any_of is not None:
            out["vertex_any_of"] = list(self.vertex_any_of)
        if self.q_any_of is not None:
            out["q_any_of"] = [list(cls) for cls in self.q_any_of]
        if self.edge_any_of is not None:
            out["edge_any_of"] = list(self.edge_any_of)
        return out

    # --------------------------------------------------------- bitset views
    # The views are memoized per (view, graph fingerprint) on the instance:
    # a mining run calls them from every expand_group step and the
    # restricted-adjacency OR-reduce over [T, N, W] planes is far more
    # expensive than the probe it feeds.  The memo rides __dict__ (the
    # cached_property idiom), so frozen-ness, ==, and hash are unaffected.
    def _memo(self, name: str, graph: GraphStore, build):
        memo = self.__dict__.setdefault("_view_memo", {})
        key = (name, graph.fingerprint)
        if key not in memo:
            memo[key] = build()
        return memo[key]

    def vertex_bits(self, graph: GraphStore) -> Optional[np.ndarray]:
        """Packed ``[W] uint32`` bitset of vertices satisfying
        ``vertex_any_of`` (``None`` when the component is absent)."""
        if self.vertex_any_of is None:
            return None
        return self._memo("vertex_bits", graph,
                          lambda: bitset.from_bool(self.vertex_mask(graph)))

    def vertex_mask(self, graph: GraphStore) -> Optional[np.ndarray]:
        """Boolean ``[N]`` form of :meth:`vertex_bits`."""
        if self.vertex_any_of is None:
            return None
        return self._memo(
            "vertex_mask", graph,
            lambda: np.isin(np.asarray(graph.labels), self.vertex_any_of))

    def adjacency(self, graph: GraphStore) -> np.ndarray:
        """``[N, W] uint32`` adjacency restricted to allowed edge types
        (the full adjacency when ``edge_any_of`` is absent)."""
        if self.edge_any_of is None:
            return graph.adj_bits
        return self._memo(
            "adjacency", graph,
            lambda: np.bitwise_or.reduce(
                graph.etype_adj_bits[list(self.edge_any_of)], axis=0))

    def edge_mask_csr(self, graph: GraphStore) -> Optional[np.ndarray]:
        """Boolean ``[M2]`` mask over the CSR ``indices`` slots whose edge
        type is allowed (``None`` when ``edge_any_of`` is absent)."""
        if self.edge_any_of is None:
            return None
        return self._memo(
            "edge_mask_csr", graph,
            lambda: np.isin(np.asarray(graph.edge_labels),
                            self.edge_any_of))
