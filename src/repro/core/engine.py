"""Batched prioritized subgraph-expansion engine (paper Algorithm 1, TPU form).

One engine *super-step* replaces the paper's per-subgraph loop iteration:

1. **dequeue** the ``B`` highest-priority states from the device pool
   (``jax.lax.top_k`` — the priority queue's ``remove_max``, B-wide);
2. **result insertion** — merge relevant dequeued states into the top-k
   result set (Alg. 1 lines 6-10);
3. **pruning** — the k-th result key is the dominance threshold; dequeued
   states with ``upper_bound < threshold`` are dropped (line 11), candidate
   children with ``child_ub < threshold`` are never materialized (line 15);
4. **targeted expansion** — ``score_children`` yields priorities for the
   valid (state, action) grid only (line 13); parents are expanded greedily
   in priority order while their total child count fits the materialization
   budget ``M`` — parents that don't fit are *re-inserted unexpanded*, so no
   child is ever lost (completeness);
5. **insert** — pool ∪ children ∪ unexpanded parents are merge-sorted by
   priority; the top ``C`` stay on device, the rest exit as a fixed-size
   overflow block for the virtual priority queue to spill.

Distribution: :func:`make_sharded_bound_sync` builds the one collective the
distributed engine needs — an all-gather of per-shard result keys so every
shard prunes against the *global* k-th best (DESIGN.md §4).  The whole
super-step body (``_step_impl``) takes an optional ``bound_sync`` hook, so
:class:`repro.distributed.ShardedEngine` runs the identical code per shard
inside ``shard_map`` — the single-device :class:`Engine` is exactly the
1-shard specialization (DESIGN.md §11).

Macro-stepping (DESIGN.md §13): with ``EngineConfig.steps_per_sync = T > 1``
the engine fuses up to ``T`` super-steps into one jitted
``jax.lax.while_loop`` over ``_step_impl`` (``_macro_impl``), accumulating
stats and overflow in a fixed-capacity on-device buffer, so the host↔device
round-trip — ``device_get`` of the stats, Python dispatch, the overflow
ship-out — is paid once per *macro*-step instead of once per super-step.
The loop early-exits back to the host exactly when host work is due: the
pool dips under the ``C/2`` refill watermark while spill exists, the
overflow accumulator cannot fit another block, or the pool drains.  The
macro jit donates the pool buffers on backends that support donation, so
the ``C×S`` pool is updated in place instead of copied every step.
"""
from __future__ import annotations

import dataclasses
import os
import time
from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from .api import NEG, SubgraphComputation
from .vpq import VirtualPriorityQueue
from repro.obs import NOOP, Observability

# EngineState counters checkpointed verbatim (DESIGN.md §15)
_CKPT_SCALARS = ("steps", "candidates", "expanded", "pruned", "refilled",
                 "syncs", "host_syncs", "threshold", "pool_occupancy",
                 "done")


def donatable_pool_argnums():
    """Pool-buffer argnums the macro-step jit may donate (DESIGN.md §13).

    The pool arrays (args 0-2: ``pool_states``/``pool_prio``/``pool_ub``,
    ``C×S`` + 2×``C``) are pure state-in/state-out, so donation lets XLA
    update them in place instead of copying every macro-step.  CPU has no
    donation support (XLA warns and copies anyway), so donate only where
    it is implemented.
    """
    return (0, 1, 2) if jax.default_backend() in ("gpu", "tpu") else ()


@dataclasses.dataclass
class EngineConfig:
    k: int = 1                    # result set size
    batch: int = 64               # B: states dequeued per super-step
    pool_capacity: int = 4096     # C: device-resident priority pool slots
    max_children: Optional[int] = None  # M: materialization budget (>= A)
    max_steps: int = 100_000
    spill: str = "host"           # VPQ backing: "host" | "disk" | "none"
    spill_dir: Optional[str] = None
    # device-mesh sharding (DESIGN.md §11): number of frontier shards.  The
    # single-device Engine ignores it; repro.distributed.ShardedEngine
    # seed-partitions the frontier over this many devices, with batch /
    # pool_capacity / max_children read as *per-shard* shapes.  Complete
    # runs are byte-identical for any shard count (parity-tested), but
    # budget-truncated runs are not, so like batch/pool_capacity — and
    # unlike the per-step-identical kernel knobs below — it enters the
    # service result-cache key.
    shards: int = 1
    # macro-stepping (DESIGN.md §13): number of super-steps fused into one
    # jitted while_loop between host syncs.  1 (default) preserves the
    # classic one-jit-call-per-step behavior; T > 1 amortizes dispatch /
    # device_get latency over T steps.  Complete runs are byte-identical
    # for any T (parity-tested) — like the kernel knobs, and unlike
    # batch/pool_capacity, it is excluded from the service result-cache
    # key; budget-truncated runs stop at the same step count for any T
    # (the macro loop is capped to the remaining budget) but may differ
    # in spill-run tie order.
    steps_per_sync: int = 1
    # capacity (entries) of the on-device overflow accumulator used by the
    # fused loop; None sizes it to steps_per_sync * (B + M) — enough that
    # it can never fill mid-macro-step.  Smaller values trade memory for
    # earlier syncs (the loop exits when the next block might not fit);
    # values below B + M are raised to B + M.
    overflow_accum: Optional[int] = None
    # staleness-tolerant bound exchange (DESIGN.md §14): number of inner
    # super-steps the sharded engine runs between §4 `bound_sync`
    # all-gathers.  Between exchanges every shard prunes against
    # max(last-exchanged global bound, its own fresh local k-th best) —
    # both are lower bounds on the fresh global k-th best, so the interim
    # threshold is only ever *looser* than the fresh one and complete
    # runs stay byte-identical for any value (property-tested in
    # tests/test_stale_bound.py), while collectives drop by a factor of
    # K.  Like steps_per_sync it is excluded from the service
    # result-cache key (budget truncation still lands on the same step
    # count) but included in the engine-reuse key.  The single-device
    # Engine has no collective to amortize and ignores it.  K > 1
    # implies macro-stepping: the sharded engine raises the fused length
    # to the next multiple of K so every fused call ends on an exchange
    # boundary, and clamps K so a full K-step segment always fits the
    # overflow accumulator.
    sync_every: int = 1
    # debug/test hook (tests/test_stale_bound.py): record, per fused
    # inner step, the threshold each shard actually pruned with and the
    # fresh global bound a per-step exchange would have produced
    # (surfaced via EngineResult.per_shard["bound_used"/"bound_fresh"]).
    # Costs one extra all-gather per stale step — never enable outside
    # tests.
    record_bound_trace: bool = False
    # durable runs (DESIGN.md §15): with checkpoint_every = N > 0 and a
    # checkpoint_dir, Engine.run()/ShardedEngine.run() persist the full
    # engine state (pool, results, VPQ runs, counters) through
    # CheckpointManager's atomic-commit protocol at the first host-sync
    # boundary every >= N steps, and Engine.resume() reconstructs an
    # EngineState whose continued run is byte-identical to an
    # uninterrupted one (same top-k, same step trajectory — the same
    # invariant discipline as shards/T/K, crash-proved in
    # tests/test_fault_injection.py).  Checkpoints are pure observers of
    # host-sync state, so like the kernel knobs both fields are excluded
    # from the service result-cache key (but included in the engine-reuse
    # key: tasks sharing an engine share its checkpoint policy).
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None
    # kernel-path knobs (DESIGN.md §10): a declarative record consumed at
    # computation-construction time (service.api.compile_request reads
    # them when calling make_*_computation) — NOT by the engine loop,
    # which is kernel-agnostic.  Setting them here does not retrofit a
    # computation you already built; direct Engine callers must pass the
    # knobs to make_*_computation themselves.  Both settings leave results
    # byte-identical (parity-tested), so they are also excluded from the
    # service result-cache key.
    use_pallas: bool = False      # score via the Pallas masked-intersection
    interpret: Optional[bool] = None  # None = auto-detect backend
    # observability (DESIGN.md §16): observe=True routes the engine's
    # metrics/spans into a live repro.obs.Observability instead of the
    # process-global no-op.  A pure observer like checkpointing — results
    # are byte-identical either way (parity-tested across shard counts
    # and T in tests/test_obs.py) — so it is excluded from the service
    # result-cache key but included in the engine-reuse key.
    # ``observability`` optionally injects a shared instance (the service
    # layer passes its own so per-request and per-engine telemetry land
    # in one registry); None with observe=True creates a private one.
    observe: bool = False
    observability: Optional[object] = None


@dataclasses.dataclass
class EngineResult:
    result_states: np.ndarray     # [k, S]
    result_keys: np.ndarray       # [k] (NEG = empty slot)
    steps: int
    candidates: int               # subgraphs materialized (paper metric 1)
    expanded: int                 # subgraphs actually expanded
    pruned: int                   # dequeued states dropped by dominance
    spilled: int
    refilled: int
    rebalanced: int = 0           # spilled entries moved across shards (§11)
    late_pruned: int = 0          # dominated entries dropped at VPQ refill
    # bound-exchange collectives actually run (§14): ceil(steps /
    # sync_every) per fused call for the sharded engine; 0 for the
    # single-device engine, which computes its threshold locally and
    # never talks to another shard
    syncs: int = 0
    host_syncs: int = 0           # host↔device round-trips (== steps at T=1)
    per_shard: Optional[dict] = None  # ShardedEngine: per-shard stat lists


@dataclasses.dataclass
class EngineState:
    """Resumable per-query engine state (DESIGN.md §9).

    One super-step maps ``EngineState -> EngineState``; :meth:`Engine.run`
    is just a loop over :meth:`Engine.step`, which lets an external
    scheduler (``repro.service.scheduler``) interleave super-steps of many
    live queries on one device without any engine changes.
    """

    pool_states: jnp.ndarray      # [C, S]
    pool_prio: jnp.ndarray        # [C]
    pool_ub: jnp.ndarray          # [C]
    result_states: jnp.ndarray    # [k, S]
    result_keys: jnp.ndarray      # [k]
    vpq: VirtualPriorityQueue
    steps: int = 0
    candidates: int = 0
    expanded: int = 0
    pruned: int = 0
    refilled: int = 0
    syncs: int = 0                # bound-exchange collectives (0 unsharded)
    host_syncs: int = 0           # host↔device round-trips taken so far
    threshold: int = int(NEG)
    pool_occupancy: int = 0
    done: bool = False            # pool and VPQ both drained


def merge_topk(states: jnp.ndarray, keys: jnp.ndarray, k: int):
    """Canonical top-k selection over result candidates: key descending,
    ties broken by the state words lexicographically ascending (signed
    int32 order, word 0 most significant), duplicates collapsed.

    Candidates may contain the same (state, key) pair more than once — a
    deferred parent re-enters the pool and contributes its result key again
    on re-dequeue, and per-shard result sets can both have seen a state the
    rebalancer moved.  Duplicates are adjacent after the lexicographic sort
    and all but the first are demoted to empty, so one state can never
    occupy two result slots (which would both displace the true k-th result
    and tighten the dominance threshold unsoundly).

    Dedup plus the deterministic tie-break make the result set a pure
    function of the *set* of discovered (state, key) pairs — insertion
    order and multiplicity cannot change the outcome — which is what lets
    a sharded run (any shard count, any interleaving) reproduce the
    single-device result set byte-for-byte (DESIGN.md §11).  States in
    empty slots (key == NEG) are zeroed so they too are byte-stable.
    """
    s = states.shape[-1]
    # key is the least-significant sort column so equal states cluster by
    # key too — without it a NEG-keyed copy sorted between two real-keyed
    # copies of the same state would hide them from the adjacency check
    lex = jnp.lexsort((keys,) + tuple(states[:, j]
                                      for j in reversed(range(s))))
    ss, kk = states[lex], keys[lex]
    dup = jnp.concatenate([
        jnp.zeros((1,), bool),
        jnp.all(ss[1:] == ss[:-1], axis=1) & (kk[1:] == kk[:-1])])
    kk = jnp.where(dup, NEG, kk)
    top = jnp.argsort(kk, stable=True, descending=True)[:k]
    top_keys = kk[top]
    top_states = jnp.where((top_keys > NEG)[:, None], ss[top], 0)
    return top_states, top_keys


class Engine:
    """Runs one :class:`SubgraphComputation` to completion (or stepwise)."""

    def __init__(self, comp: SubgraphComputation, config: EngineConfig):
        self.comp = comp
        self.cfg = config
        a = comp.num_actions
        self.M = max(config.max_children or 0, a)
        self.B = config.batch
        self.C = config.pool_capacity
        self.S = comp.state_width
        self.k = config.k
        self.T = max(1, config.steps_per_sync)
        # overflow-accumulator capacity: one super-step's overflow block is
        # exactly B + M entries (the merge-sort insert over C + M + B rows
        # keeps C), so T blocks can never overflow the default sizing
        self.acc_cap = max(config.overflow_accum or self.T * (self.B + self.M),
                           self.B + self.M)
        self._step = jax.jit(self._step_impl)
        self._insert = jax.jit(self._insert_impl)
        if self.T > 1:
            self._macro = jax.jit(self._macro_impl,
                                  donate_argnums=donatable_pool_argnums())
        # observability (DESIGN.md §16): metric handles are resolved once
        # here — the step loop touches the metric objects directly, never
        # the registry.  With observe off every handle is the shared
        # null metric and self._span returns the shared null span.
        if config.observe:
            self.obs = config.observability or Observability()
        else:
            self.obs = NOOP
        obs = self.obs
        self._span = obs.tracer.span
        self._m_steps = obs.counter(
            "engine_steps_total", "engine super-steps completed")
        self._m_host_syncs = obs.counter(
            "engine_host_syncs_total", "host-device round-trips")
        self._m_candidates = obs.counter(
            "engine_candidates_total", "subgraphs materialized")
        self._m_expanded = obs.counter(
            "engine_expanded_total", "subgraphs expanded")
        self._m_pruned = obs.counter(
            "engine_pruned_total", "dequeued states dropped by dominance")
        self._m_refilled = obs.counter(
            "engine_refilled_total", "pool entries refilled from spill")
        self._g_occupancy = obs.gauge(
            "engine_pool_occupancy", "live device-pool entries")
        self._g_threshold = obs.gauge(
            "engine_threshold", "current dominance threshold (k-th key)")
        self._h_step = obs.histogram(
            "engine_step_seconds", "wall time per engine step() call")

    # ------------------------------------------------------------------ step
    def _step_impl(self, pool_states, pool_prio, pool_ub,
                   result_states, result_keys, bound_sync=None):
        """One super-step.  ``bound_sync`` (None for the single-device
        engine) maps the local result keys to the pruning threshold; the
        sharded engine passes :func:`make_sharded_bound_sync`'s collective
        so every shard prunes against the global k-th best (DESIGN.md §11).
        """
        comp, B, M, C, k = self.comp, self.B, self.M, self.C, self.k
        A = comp.num_actions

        # 1. dequeue top-B
        prio_b, idx_b = jax.lax.top_k(pool_prio, B)
        valid_b = prio_b > NEG
        states_b = pool_states[idx_b]
        ub_b = pool_ub[idx_b]
        pool_prio = pool_prio.at[idx_b].set(NEG)

        # 2. result insertion (Alg. 1 lines 6-10), canonical tie-break
        rkey_b = jnp.where(valid_b, comp.result_key(states_b), NEG)
        merged_keys = jnp.concatenate([result_keys, rkey_b])
        merged_states = jnp.concatenate([result_states, states_b])
        result_states, result_keys = merge_topk(merged_states, merged_keys, k)

        # 3. dominance threshold (the k-th entry; NEG while R not full);
        #    under a bound_sync this is the *global* k-th best
        if bound_sync is None:
            threshold = jnp.where(result_keys[k - 1] > NEG,
                                  result_keys[k - 1], NEG)
        else:
            threshold = bound_sync(result_states, result_keys)
        expand_b = valid_b & (ub_b >= threshold)
        pruned = jnp.sum(valid_b & ~expand_b)

        # 4. targeted expansion: score the [B, A] child grid
        child_prio, child_ub = comp.score_children(states_b)
        keep = expand_b[:, None] & (child_prio > NEG) & (child_ub >= threshold)

        # greedy parent admission: expand parents (already sorted by priority)
        # while cumulative child count fits M; the rest re-enter the pool.
        counts = jnp.sum(keep, axis=1)
        fits = jnp.cumsum(counts) <= M
        admitted = expand_b & fits
        deferred = valid_b & expand_b & ~fits          # re-insert unexpanded
        keep = keep & admitted[:, None]

        flat_prio = jnp.where(keep, child_prio, NEG).reshape(B * A)
        top_cp, top_ci = jax.lax.top_k(flat_prio, M)
        sel_valid = top_cp > NEG
        sel_parent = top_ci // A
        sel_action = (top_ci % A).astype(jnp.int32)
        child_states = comp.materialize(states_b[sel_parent], sel_action)
        child_states = jnp.where(sel_valid[:, None], child_states, 0)
        child_ub_sel = jnp.where(
            sel_valid, child_ub.reshape(B * A)[top_ci], NEG)
        child_prio_sel = jnp.where(sel_valid, top_cp, NEG)

        # 5. merge-sort insert: pool ∪ children ∪ deferred parents
        def_prio = jnp.where(deferred, prio_b, NEG)
        cat_prio = jnp.concatenate([pool_prio, child_prio_sel, def_prio])
        cat_ub = jnp.concatenate([pool_ub, child_ub_sel,
                                  jnp.where(deferred, ub_b, NEG)])
        cat_states = jnp.concatenate([pool_states, child_states, states_b])
        order = jnp.argsort(cat_prio, descending=True)
        pool_prio = cat_prio[order[:C]]
        pool_ub = cat_ub[order[:C]]
        pool_states = cat_states[order[:C]]
        over = order[C:]
        overflow = (cat_states[over], cat_prio[over], cat_ub[over])

        stats = dict(
            dequeued=jnp.sum(valid_b).astype(jnp.int32),
            expanded=jnp.sum(admitted).astype(jnp.int32),
            created=jnp.sum(sel_valid).astype(jnp.int32),
            pruned=pruned.astype(jnp.int32),
            pool_occupancy=jnp.sum(pool_prio > NEG).astype(jnp.int32),
            threshold=threshold,
        )
        return (pool_states, pool_prio, pool_ub, result_states, result_keys,
                overflow, stats)

    # ------------------------------------------------------------ macro-step
    def _macro_impl(self, pool_states, pool_prio, pool_ub,
                    result_states, result_keys, t_max, vpq_nonempty, occ0,
                    bound_sync=None, any_reduce=None, sync_every=1,
                    stale_sync=None, record_bounds=False):
        """Up to ``t_max`` fused super-steps in one ``lax.while_loop``
        (DESIGN.md §13).  Per-step overflow blocks land in a fixed
        ``[acc_cap, S]`` on-device accumulator — each block is written at
        the valid-entry watermark ``w`` and, because blocks exit the
        merge-sort insert sorted by descending priority, their valid
        entries are a prefix, so advancing ``w`` by the valid count packs
        the accumulator densely and the host ships exactly ``acc[:w]``.

        The loop hands control back to the host exactly when host work is
        due, i.e. it continues only while (a) steps remain, (b) the next
        overflow block (segment of blocks under ``sync_every > 1``) is
        guaranteed to fit, (c) the pool is non-empty, and (d) no refill is
        possible — the pool is at or above the ``C//2`` watermark, or
        nothing is spilled (VPQ empty at entry and accumulator empty).
        (d) reproduces the unfused refill cadence step-for-step: the fused
        engine syncs at the first step whose unfused counterpart would
        have refilled.

        ``bound_sync`` / ``any_reduce`` are the sharded engine's hooks:
        the first is the §4 threshold collective, the second reduces
        per-shard continue/stop votes to a global decision so all shards
        leave the loop together and the in-loop collectives stay aligned.
        The continue flag is computed in the loop *body* and carried, so
        the ``while_loop`` cond stays collective-free.

        ``sync_every = K > 1`` selects the staleness-tolerant cadence
        (DESIGN.md §14): each loop iteration is one *segment* — a head
        step that runs the fresh ``bound_sync`` exchange followed by up
        to ``K - 1`` tail steps whose threshold is
        ``stale_sync(last exchange, local result keys)``, a bound that is
        only ever *looser* than the fresh one (so pruning stays sound and
        complete runs byte-identical) — and the continue/stop votes are
        reduced once per segment instead of once per step, so collectives
        drop by a factor of K.  Tail steps run unconditionally (a drained
        shard pads with no-op steps until the boundary) so every shard
        reaches each collective together.  ``record_bounds`` additionally
        journals, per inner step, the threshold actually used and the
        fresh global bound a per-step exchange would have produced
        (``stats["bound_used"/"bound_fresh"]``, valid prefix ``steps``) —
        the §14 staleness invariant made observable for tests.
        """
        if sync_every <= 1 and not record_bounds:
            return self._macro_flat(
                pool_states, pool_prio, pool_ub, result_states, result_keys,
                t_max, vpq_nonempty, occ0, bound_sync, any_reduce)
        return self._macro_segmented(
            pool_states, pool_prio, pool_ub, result_states, result_keys,
            t_max, vpq_nonempty, occ0, bound_sync, any_reduce,
            max(1, sync_every), stale_sync, record_bounds)

    def _cont_flag(self, seg_blocks, vpq_nonempty, any_reduce,
                   t_max, t_next, w, occ):
        """Continue/stop decision shared by both macro variants:
        ``seg_blocks`` is the number of overflow blocks the next loop
        iteration may produce (1 flat, K segmented)."""
        C, cap = self.C, self.acc_cap
        room = (w + seg_blocks * (self.B + self.M)) <= cap
        active = occ > 0
        low = occ < (C // 2)
        refillable = vpq_nonempty | (w > 0)
        if any_reduce is None:
            need_host = jnp.logical_not(room) | (low & refillable)
            cont = jnp.logical_not(need_host) & active
        else:
            # per-shard votes -> one global decision: stop when ANY
            # shard needs host service (its own refill moment or a
            # full accumulator), keep going while ANY shard is active;
            # refill-ability is global because the host rebalancer can
            # move any shard's spill to any starving shard
            need_host = jnp.logical_not(room) | \
                (low & any_reduce(refillable))
            cont = jnp.logical_not(any_reduce(need_host)) & \
                any_reduce(active)
        return (t_next < t_max) & cont

    def _fused_step(self, ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums,
                    sync_fn):
        """One inner super-step plus overflow-accumulator/stat packing —
        the body both macro variants repeat."""
        ps, pp, pu, rs, rk, (o_s, o_p, o_u), stats = self._step_impl(
            ps, pp, pu, rs, rk, bound_sync=sync_fn)
        cnt = jnp.sum(o_p > NEG).astype(jnp.int32)
        acc_s = jax.lax.dynamic_update_slice(acc_s, o_s, (w, 0))
        acc_p = jax.lax.dynamic_update_slice(acc_p, o_p, (w,))
        acc_u = jax.lax.dynamic_update_slice(acc_u, o_u, (w,))
        w = w + cnt
        sums = {name: sums[name] + stats[name]
                for name in ("expanded", "created", "pruned")}
        return ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums, stats

    def _macro_flat(self, pool_states, pool_prio, pool_ub,
                    result_states, result_keys, t_max, vpq_nonempty, occ0,
                    bound_sync, any_reduce):
        """The ``sync_every == 1`` macro loop: one step per iteration, the
        §4 exchange (when sharded) and the exit vote every inner step."""
        S, cap = self.S, self.acc_cap
        cont_flag = partial(self._cont_flag, 1, vpq_nonempty, any_reduce,
                            t_max)

        def body(carry):
            (t, ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums, _occ,
             _thr, _cont) = carry
            (ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums, stats) = \
                self._fused_step(ps, pp, pu, rs, rk, acc_s, acc_p, acc_u,
                                 w, sums, bound_sync)
            occ = stats["pool_occupancy"]
            return (t + 1, ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w,
                    sums, occ, stats["threshold"],
                    cont_flag(t + 1, w, occ))

        zero = jnp.int32(0)
        carry = (zero, pool_states, pool_prio, pool_ub,
                 result_states, result_keys,
                 jnp.zeros((cap, S), jnp.int32),
                 jnp.full((cap,), NEG, jnp.int32),
                 jnp.full((cap,), NEG, jnp.int32),
                 zero, dict(expanded=zero, created=zero, pruned=zero),
                 jnp.asarray(occ0, jnp.int32), jnp.int32(NEG),
                 jnp.asarray(True))  # the first inner step always runs
        (t, ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums, occ, thr,
         _cont) = jax.lax.while_loop(lambda c: c[-1], body, carry)
        stats = dict(sums, steps=t, spill_count=w, pool_occupancy=occ,
                     threshold=thr)
        return ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, stats

    def _macro_segmented(self, pool_states, pool_prio, pool_ub,
                         result_states, result_keys, t_max, vpq_nonempty,
                         occ0, bound_sync, any_reduce, sync_every,
                         stale_sync, record_bounds):
        """The ``sync_every = K > 1`` macro loop (DESIGN.md §14): each
        iteration runs one K-step segment — fresh exchange at the head,
        stale-bound tail, one vote at the boundary.  Collective-free when
        ``bound_sync is None`` (single-device with ``record_bound_trace``):
        the head threshold is then the local k-th best and the stale/fresh
        traces coincide by construction."""
        S, cap, K, k = self.S, self.acc_cap, sync_every, self.k
        cont_flag = partial(self._cont_flag, K, vpq_nonempty, any_reduce,
                            t_max)
        if stale_sync is None:
            stale_sync = make_stale_bound_sync(k)

        def fresh_fn(srs, srk):   # what a per-step exchange would produce
            if bound_sync is not None:
                return bound_sync(srs, srk)
            return jnp.where(srk[k - 1] > NEG, srk[k - 1], NEG)

        def body(carry):
            (t, ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums, _occ,
             _stale, _cont, tr_u, tr_f) = carry
            # segment head: the fresh §4 exchange becomes this segment's
            # carried global bound
            (ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums, stats) = \
                self._fused_step(ps, pp, pu, rs, rk, acc_s, acc_p, acc_u,
                                 w, sums, bound_sync)
            stale = stats["threshold"]
            occ = stats["pool_occupancy"]
            if record_bounds:
                tr_u = tr_u.at[t].set(stale)
                tr_f = tr_f.at[t].set(stale)
            t = t + 1

            def tail_step(_i, c):
                (t_i, ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums,
                 _o, tr_u, tr_f) = c
                box = {}

                def sync_fn(srs, srk):
                    used = stale_sync(stale, srk)
                    box["used"] = used
                    if record_bounds:
                        box["fresh"] = fresh_fn(srs, srk)
                    return used

                (ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums,
                 stats) = self._fused_step(ps, pp, pu, rs, rk, acc_s,
                                           acc_p, acc_u, w, sums, sync_fn)
                if record_bounds:
                    tr_u = tr_u.at[t_i].set(box["used"])
                    tr_f = tr_f.at[t_i].set(box["fresh"])
                return (t_i + 1, ps, pp, pu, rs, rk, acc_s, acc_p, acc_u,
                        w, sums, stats["pool_occupancy"], tr_u, tr_f)

            # tail steps run unconditionally to the segment boundary (or
            # the step budget) so every shard meets the next collective;
            # a drained shard's extra steps dequeue nothing and are no-ops
            n_tail = jnp.minimum(jnp.int32(K - 1), t_max - t)
            (t, ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums, occ,
             tr_u, tr_f) = jax.lax.fori_loop(
                jnp.int32(0), n_tail, tail_step,
                (t, ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums, occ,
                 tr_u, tr_f))
            return (t, ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums,
                    occ, stale, cont_flag(t, w, occ), tr_u, tr_f)

        zero = jnp.int32(0)
        trace = jnp.full((self.T,), NEG, jnp.int32)
        carry = (zero, pool_states, pool_prio, pool_ub,
                 result_states, result_keys,
                 jnp.zeros((cap, S), jnp.int32),
                 jnp.full((cap,), NEG, jnp.int32),
                 jnp.full((cap,), NEG, jnp.int32),
                 zero, dict(expanded=zero, created=zero, pruned=zero),
                 jnp.asarray(occ0, jnp.int32), jnp.int32(NEG),
                 jnp.asarray(True),   # the first segment always runs
                 trace, trace)
        (t, ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, w, sums, occ, stale,
         _cont, tr_u, tr_f) = jax.lax.while_loop(
            lambda c: c[13], body, carry)
        # report the *exchanged* bound (replicated across shards) as the
        # macro threshold: the host's late-pruning cutoff must be a global
        # lower bound, and stale is exactly that (§14 soundness)
        stats = dict(sums, steps=t, spill_count=w, pool_occupancy=occ,
                     threshold=stale)
        if record_bounds:
            stats["bound_used"] = tr_u
            stats["bound_fresh"] = tr_f
        return ps, pp, pu, rs, rk, acc_s, acc_p, acc_u, stats

    # ---------------------------------------------------------------- insert
    def _insert_impl(self, pool_states, pool_prio, pool_ub,
                     new_states, new_prio, new_ub):
        C = self.C
        cat_prio = jnp.concatenate([pool_prio, new_prio])
        cat_ub = jnp.concatenate([pool_ub, new_ub])
        cat_states = jnp.concatenate([pool_states, new_states])
        order = jnp.argsort(cat_prio, descending=True)
        over = order[C:]
        return (cat_states[order[:C]], cat_prio[order[:C]], cat_ub[order[:C]],
                cat_states[over], cat_prio[over], cat_ub[over])

    # ----------------------------------------------------------------- start
    def start(self) -> EngineState:
        """Seed the frontier and return a resumable :class:`EngineState`."""
        with self._span("engine.start"):
            return self._start_impl()

    def _start_impl(self) -> EngineState:
        cfg, S, C, k = self.cfg, self.S, self.C, self.k
        vpq = VirtualPriorityQueue(
            state_width=S, backend=cfg.spill, spill_dir=cfg.spill_dir,
            obs=self.obs)

        states0, prio0, ub0 = self.comp.init_frontier()
        n0 = states0.shape[0]

        pool_states = jnp.zeros((C, S), jnp.int32)
        pool_prio = jnp.full((C,), NEG, jnp.int32)
        pool_ub = jnp.full((C,), NEG, jnp.int32)
        if n0 <= C:
            pool_states, pool_prio, pool_ub, os_, op_, ou_ = self._insert(
                pool_states, pool_prio, pool_ub, states0, prio0, ub0)
            vpq.maybe_push(np.asarray(os_), np.asarray(op_), np.asarray(ou_))
        else:  # more seeds than pool slots: top-C on device, rest spilled
            order = np.argsort(-np.asarray(prio0), kind="stable")
            states0, prio0, ub0 = (np.asarray(states0)[order],
                                   np.asarray(prio0)[order],
                                   np.asarray(ub0)[order])
            pool_states = jnp.asarray(states0[:C])
            pool_prio = jnp.asarray(prio0[:C])
            pool_ub = jnp.asarray(ub0[:C])
            vpq.maybe_push(states0[C:], prio0[C:], ub0[C:])

        return EngineState(
            pool_states=pool_states, pool_prio=pool_prio, pool_ub=pool_ub,
            result_states=jnp.zeros((k, S), jnp.int32),
            result_keys=jnp.full((k,), NEG, jnp.int32),
            vpq=vpq, candidates=int(n0), pool_occupancy=min(int(n0), C))

    # ------------------------------------------------------------------ step
    def step(self, st: EngineState, max_inner: Optional[int] = None
             ) -> EngineState:
        """Advance one engine step — a single super-step at
        ``steps_per_sync == 1``, else one fused *macro*-step of up to
        ``min(steps_per_sync, max_inner)`` super-steps (DESIGN.md §13).
        ``max_inner`` caps the fused super-step count so external step
        budgets (``max_steps``, the service ``step_budget``) truncate at
        exactly the same step count for any ``steps_per_sync``.  Updates
        ``st`` in place and returns it.
        """
        t0 = time.perf_counter() if self.obs.enabled else 0.0
        if self.T == 1:
            with self._span("engine.step"):
                # attribution caveat (docs/OBSERVABILITY.md): jax dispatch
                # is async, so on accelerators part of the compute lands
                # in the host_sync span where device_get blocks
                with self._span("engine.device_compute"):
                    (st.pool_states, st.pool_prio, st.pool_ub,
                     st.result_states, st.result_keys, overflow,
                     stats) = self._step(
                        st.pool_states, st.pool_prio, st.pool_ub,
                        st.result_states, st.result_keys)
                with self._span("engine.host_sync"):
                    stats = jax.tree.map(int, jax.device_get(stats))
                st.steps += 1
                st.host_syncs += 1
                st.expanded += stats["expanded"]
                st.candidates += stats["created"]
                st.pruned += stats["pruned"]
                st.threshold = stats["threshold"]
                with self._span("engine.spill"):
                    st.vpq.maybe_push(*map(np.asarray, overflow))
                self._refill(st, stats["pool_occupancy"])
            self._after_step(st, 1, stats, t0)
            return st

        t_cap = (self.T if max_inner is None
                 else max(1, min(self.T, int(max_inner))))
        with self._span("engine.step"):
            with self._span("engine.device_compute"):
                (st.pool_states, st.pool_prio, st.pool_ub,
                 st.result_states, st.result_keys, acc_s, acc_p, acc_u,
                 stats) = self._macro(
                    st.pool_states, st.pool_prio, st.pool_ub,
                    st.result_states, st.result_keys,
                    np.int32(t_cap), len(st.vpq) > 0,
                    np.int32(st.pool_occupancy))
            with self._span("engine.host_sync"):
                stats = jax.tree.map(int, jax.device_get(stats))
            st.steps += stats["steps"]
            st.host_syncs += 1
            st.expanded += stats["expanded"]
            st.candidates += stats["created"]
            st.pruned += stats["pruned"]
            st.threshold = stats["threshold"]
            w = stats["spill_count"]
            if w:  # ship only the accumulator's valid prefix; none when dry
                with self._span("engine.spill"):
                    st.vpq.maybe_push(np.asarray(acc_s)[:w],
                                      np.asarray(acc_p)[:w],
                                      np.asarray(acc_u)[:w])
            self._refill(st, stats["pool_occupancy"])
        self._after_step(st, stats["steps"], stats, t0)
        return st

    def _after_step(self, st: EngineState, n_steps: int, stats: dict,
                    t0: float) -> None:
        """Record one step() call's metrics (no-op handles when off)."""
        self._m_steps.inc(n_steps)
        self._m_host_syncs.inc()
        self._m_expanded.inc(stats["expanded"])
        self._m_candidates.inc(stats["created"])
        self._m_pruned.inc(stats["pruned"])
        self._g_occupancy.set(st.pool_occupancy)
        self._g_threshold.set(st.threshold)
        if self.obs.enabled:
            self._h_step.observe(time.perf_counter() - t0)

    # ---------------------------------------------------------------- refill
    def _refill(self, st: EngineState, occ: int) -> None:
        """Refill the pool from spill when under the C/2 watermark; sets
        ``pool_occupancy`` and ``done``."""
        C = self.C
        refilled_now = 0
        if occ < C // 2 and len(st.vpq):
            # refill from spill runs; entries dominated by the current
            # threshold are dropped at the VPQ (paper-style late pruning)
            with self._span("engine.refill"):
                r_states, r_prio, r_ub = st.vpq.pop_chunk(
                    C - occ, min_ub=st.threshold)
                if len(r_prio):
                    refilled_now = len(r_prio)
                    st.refilled += refilled_now
                    self._m_refilled.inc(refilled_now)
                    (st.pool_states, st.pool_prio, st.pool_ub,
                     os_, op_, ou_) = self._insert(
                        st.pool_states, st.pool_prio, st.pool_ub,
                        jnp.asarray(r_states), jnp.asarray(r_prio),
                        jnp.asarray(r_ub))
                    st.vpq.maybe_push(np.asarray(os_), np.asarray(op_),
                                      np.asarray(ou_))
        # refilled entries are live in the pool (their priorities are > NEG),
        # so a refill that drained the VPQ must not read as completion
        st.pool_occupancy = occ + refilled_now
        st.done = st.pool_occupancy == 0 and len(st.vpq) == 0

    # -------------------------------------------------------------- finalize
    def finalize(self, st: EngineState) -> EngineResult:
        """Close the VPQ and package the result set."""
        with self._span("engine.finalize"):
            st.vpq.close()
            return self._package(st)

    def _package(self, st: EngineState) -> EngineResult:
        return EngineResult(
            result_states=np.asarray(st.result_states),
            result_keys=np.asarray(st.result_keys),
            steps=st.steps, candidates=st.candidates, expanded=st.expanded,
            pruned=st.pruned, spilled=st.vpq.total_spilled,
            refilled=st.refilled, late_pruned=st.vpq.total_late_pruned,
            syncs=st.syncs, host_syncs=st.host_syncs)

    # ------------------------------------------------------- checkpointing
    def _ckpt_arrays(self, st: EngineState) -> dict:
        return dict(pool_states=st.pool_states, pool_prio=st.pool_prio,
                    pool_ub=st.pool_ub, result_states=st.result_states,
                    result_keys=st.result_keys)

    def save_checkpoint(self, mgr, st: EngineState,
                        blocking: bool = False) -> None:
        """Persist ``st`` through ``mgr``'s atomic-commit protocol
        (DESIGN.md §15).  The VPQ capture (array snapshots + hardlinks of
        disk run files) runs synchronously before this returns, so the
        engine may keep mutating — including deleting exhausted spill
        runs — while the leaf arrays flush on the writer thread.  Pure
        observer: saving never perturbs the step trajectory."""
        scalars = {name: getattr(st, name) for name in _CKPT_SCALARS}

        def capture(tmp_dir: str) -> dict:
            vpq = st.vpq.snapshot(os.path.join(tmp_dir, "vpq"))
            return {"kind": "engine", "scalars": scalars, "vpq": vpq}

        mgr.save(st.steps, self._ckpt_arrays(st), blocking=blocking,
                 capture=capture)

    def resume(self, source, step: Optional[int] = None) -> EngineState:
        """Reconstruct an :class:`EngineState` from a committed checkpoint
        (a directory path or a :class:`CheckpointManager`); its continued
        run is byte-identical to an uninterrupted one.  Spill files
        referenced by the checkpoint are re-linked into the live spill
        dir (``cfg.spill_dir`` or a fresh temp dir), so the checkpoint
        remains restorable any number of times."""
        from repro.checkpoint.manager import CheckpointManager
        mgr = (source if isinstance(source, CheckpointManager)
               else CheckpointManager(source, obs=self.obs))
        manifest = mgr.read_manifest(step)
        step = manifest["step"]
        extra = manifest["extra"]
        if extra is None or extra.get("kind") != "engine":
            raise ValueError(
                f"step {step} in {mgr.dir} is not an engine checkpoint")
        like = {name: np.zeros(
            [int(s) for s in leaf["shape"]], np.dtype(leaf["dtype"]))
            for leaf in manifest["leaves"]
            for name in [leaf["name"]]}
        tree = mgr.restore(like, step=step)
        vpq = VirtualPriorityQueue.restore(
            extra["vpq"], os.path.join(mgr.path(step), "vpq"),
            spill_dir=self.cfg.spill_dir, obs=self.obs)
        return EngineState(
            pool_states=jnp.asarray(tree["pool_states"]),
            pool_prio=jnp.asarray(tree["pool_prio"]),
            pool_ub=jnp.asarray(tree["pool_ub"]),
            result_states=jnp.asarray(tree["result_states"]),
            result_keys=jnp.asarray(tree["result_keys"]),
            vpq=vpq, **extra["scalars"])

    # ------------------------------------------------------------------- run
    def run(self, progress_every: int = 0,
            resume: bool = False) -> EngineResult:
        """Run to completion (or ``max_steps``).  With
        ``cfg.checkpoint_every > 0`` and a ``cfg.checkpoint_dir``, the
        state is persisted at the first host-sync boundary every
        ``checkpoint_every`` steps; ``resume=True`` continues from the
        newest committed step there (fresh start if none committed)."""
        mgr = None
        if self.cfg.checkpoint_dir and (self.cfg.checkpoint_every > 0
                                        or resume):
            from repro.checkpoint.manager import CheckpointManager
            mgr = CheckpointManager(self.cfg.checkpoint_dir, obs=self.obs)
        st = None
        if resume and mgr is not None and mgr.latest_step() is not None:
            st = self.resume(mgr)
        if st is None:
            st = self.start()
        every = self.cfg.checkpoint_every
        last_ckpt = st.steps
        while not st.done and st.steps < self.cfg.max_steps:
            self.step(st, max_inner=self.cfg.max_steps - st.steps)
            if progress_every and st.steps % progress_every == 0:
                print(f"[{self.comp.name}] step={st.steps} "
                      f"occ={st.pool_occupancy} vpq={len(st.vpq)} "
                      f"thr={st.threshold} cand={st.candidates}")
            if mgr is not None and every > 0 and \
                    st.steps - last_ckpt >= every:
                self.save_checkpoint(mgr, st)
                last_ckpt = st.steps
        if mgr is not None and every > 0 and st.steps > last_ckpt:
            self.save_checkpoint(mgr, st)   # final state is restorable too
        if mgr is not None:
            mgr.wait()
        return self.finalize(st)


def make_sharded_bound_sync(axis_name: str, k: int):
    """The distributed engine's only collective: exchange per-shard result
    sets and return the *global* k-th best result key as the shared
    pruning threshold.

    Gathers each shard's k (state, key) pairs and dedups identical states
    (:func:`merge_topk`) before taking the k-th best: a deferred parent
    whose key already entered one shard's local result set can be
    rebalanced to another shard and deposit its key there too, and keys
    alone cannot distinguish that duplicate from a legitimate tie —
    double-counting it would over-tighten the threshold and prune true
    results (unsound).  All-gathering ``k * (S + 1)`` int32 per shard is
    still a few KB — pruning tightness costs near-zero bandwidth.

    Used inside ``shard_map`` when the frontier is sharded over the
    ``data`` axis (seed partitioning) — DESIGN.md §11.
    """
    def sync(local_result_states: jnp.ndarray,
             local_result_keys: jnp.ndarray) -> jnp.ndarray:
        alls = jax.lax.all_gather(local_result_states, axis_name)
        allk = jax.lax.all_gather(local_result_keys, axis_name)
        _, topk = merge_topk(alls.reshape(-1, alls.shape[-1]),
                             allk.reshape(-1), k)
        return jnp.where(topk[k - 1] > NEG, topk[k - 1], NEG)
    return sync


def make_stale_bound_sync(k: int):
    """The staleness-aware companion to :func:`make_sharded_bound_sync`
    (DESIGN.md §14): the threshold a shard prunes with *between* exchanges,
    computed with no collective at all.

    ``stale(last_exchanged, local_result_keys)`` returns
    ``max(last-exchanged global k-th best, fresh local k-th best)``.  Both
    operands are lower bounds on the current fresh global k-th best — the
    global result set only improves monotonically after the exchange, and
    any shard's local k-th best can only be dominated by the union's — so
    their max is too, which means interim pruning is at worst *looser*
    than per-step exchange and never drops a true result.  Folding the
    local k-th in (rather than the exchanged bound alone) keeps
    single-shard runs byte-identical for every ``sync_every`` and lets a
    shard that finds great results mid-segment prune aggressively without
    waiting for the next all-gather.
    """
    def stale(last_exchanged: jnp.ndarray,
              local_result_keys: jnp.ndarray) -> jnp.ndarray:
        kth = local_result_keys[k - 1]
        local = jnp.where(kth > NEG, kth, NEG)
        return jnp.maximum(last_exchanged, local)
    return stale
