"""gSpan DFS codes and pattern-oriented expansion (paper §3.3, [62]).

A pattern is a DFS code — a tuple of edges ``(i, j, li, lj)`` over discovery
ids — and a *group* is the pattern plus all of its embeddings (ordered tuples
of data vertices, one column per discovery id).  Pattern-oriented expansion
extends every embedding of a group by one rightmost-path edge; a child
pattern is kept only if its code is **minimal** (gSpan canonicality), which
yields Property 1 of the paper: all embeddings of a child pattern come from
exactly one parent group.

Embedding extension is numpy-vectorized CSR gathering (no per-embedding
Python loops); edge-existence checks use the packed bitset adjacency —
all rightmost-path backward probes of a group go through **one** batched
probe call, which runs either as numpy word-gathers (reference) or as the
masked-intersection Pallas kernel with one-hot row masks
(``use_pallas=True``, DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from . import bitset
from .graph import GraphStore
from .labels import LABEL_FILTERS, LabelPredicate

Code = Tuple[Tuple[int, int, int, int], ...]   # ((i, j, li, lj), ...)


def edge_key(e: Tuple[int, int, int, int]) -> tuple:
    """Sortable key implementing gSpan's edge order ≺ [62]: backward edges
    before forward (for extensions of the same prefix), backward by
    increasing target id, forward by *decreasing* source id (deeper
    rightmost-path vertices first), then by labels."""
    i, j, li, lj = e
    if j < i:                       # backward
        return (0, j, li, lj)
    return (1, -i, li, lj)          # forward


def code_key(code) -> tuple:
    return tuple(edge_key(e) for e in code)


# --------------------------------------------------------------- code algebra
def code_num_vertices(code: Code) -> int:
    return max(max(e[0], e[1]) for e in code) + 1


def code_vertex_labels(code: Code) -> List[int]:
    labels = [0] * code_num_vertices(code)
    for i, j, li, lj in code:
        labels[i] = li
        labels[j] = lj
    return labels


def code_rightmost_path(code: Code) -> List[int]:
    """Vertex ids on the rightmost path, root first."""
    rightmost = 0
    parent = {}
    for i, j, _, _ in code:
        if j > i:                      # forward edge
            parent[j] = i
            rightmost = max(rightmost, j)
    path = [rightmost]
    while path[-1] in parent:
        path.append(parent[path[-1]])
    return path[::-1]


def _pattern_adj(code: Code) -> List[set]:
    nv = code_num_vertices(code)
    adj = [set() for _ in range(nv)]
    for i, j, _, _ in code:
        adj[i].add(j)
        adj[j].add(i)
    return adj


def min_dfs_code(vertex_labels: Sequence[int],
                 edges: Sequence[Tuple[int, int]]) -> Code:
    """Canonical (minimal) DFS code of a small pattern graph.

    Recursive greedy construction: at every step only the extensions whose
    code-edge value is minimal (gSpan's ≺ order: backward before forward,
    backward by increasing target id, forward from deepest rightmost-path
    vertex, ties by new-vertex label) are explored; ties branch and the
    lexicographically smallest completed code wins.
    """
    nv = len(vertex_labels)
    adj = [set() for _ in range(nv)]
    eset = set()
    for a, b in edges:
        adj[a].add(b)
        adj[b].add(a)
        eset.add((min(a, b), max(a, b)))
    n_edges = len(eset)
    best: List[Optional[Code]] = [None]

    def edge_used(used, a, b):
        return (min(a, b), max(a, b)) in used

    def rec(code, order, pos, used, rmpath):
        # order: graph vertex per dfs id; pos: graph vertex -> dfs id
        if len(code) == n_edges:
            c = tuple(code)
            if best[0] is None or code_key(c) < code_key(best[0]):
                best[0] = c
            return
        if best[0] is not None and \
                code_key(code) > code_key(best[0][:len(code)]):
            return
        right = order[-1]
        # --- backward candidates from the rightmost vertex (smallest j wins)
        back = sorted(
            pos[v] for v in adj[right]
            if v in pos and pos[v] < len(order) - 1
            and not edge_used(used, right, v))
        if back:
            j = back[0]
            v = order[j]
            e = (len(order) - 1, j, vertex_labels[right], vertex_labels[v])
            rec(code + [e], order, pos,
                used | {(min(right, v), max(right, v))}, rmpath)
            return
        # --- forward candidates from the rightmost path, deepest first
        for u_id in reversed(rmpath):
            u = order[u_id]
            cands = [wv for wv in adj[u]
                     if wv not in pos and not edge_used(used, u, wv)]
            if not cands:
                continue
            lmin = min(vertex_labels[wv] for wv in cands)
            for wv in cands:
                if vertex_labels[wv] != lmin:
                    continue
                e = (u_id, len(order), vertex_labels[u], vertex_labels[wv])
                rec(code + [e], order + [wv], {**pos, wv: len(order)},
                    used | {(min(u, wv), max(u, wv))},
                    rmpath[:rmpath.index(u_id) + 1] + [len(order)])
            return          # only the deepest rmpath vertex may extend
        # disconnected remainder cannot happen for connected patterns

    # initial edges: minimal (la, lb) first
    lmin = min(min(vertex_labels[a], vertex_labels[b]) for a, b in eset)
    for a, b in eset:
        for u, v in ((a, b), (b, a)):
            if vertex_labels[u] != lmin:
                continue
            code0 = [(0, 1, vertex_labels[u], vertex_labels[v])]
            rec(code0, [u, v], {u: 0, v: 1}, {(min(u, v), max(u, v))}, [0, 1])
    return best[0]


def is_min_code(code: Code) -> bool:
    nv = code_num_vertices(code)
    labels = code_vertex_labels(code)
    edges = [(i, j) for i, j, _, _ in code]
    return min_dfs_code(labels, edges) == tuple(code)


# ------------------------------------------------------------------ the group
@dataclasses.dataclass
class PatternGroup:
    code: Code
    embeddings: np.ndarray        # [E, nv] data vertices, column = dfs id

    @property
    def num_edges(self) -> int:
        return len(self.code)

    def support(self) -> int:
        """Minimum image-based support [5]: min over pattern vertices of the
        number of distinct data vertices mapped to it."""
        if len(self.embeddings) == 0:
            return 0
        return min(len(np.unique(self.embeddings[:, c]))
                   for c in range(self.embeddings.shape[1]))


# ------------------------------------------------- vectorized data-graph ops
def _has_edge_vec(adj: np.ndarray, u: np.ndarray, v: np.ndarray) -> np.ndarray:
    word = adj[u, v // 32]
    return (word >> (v % 32).astype(np.uint32)) & 1 > 0


# per-(graph, edge-type restriction) device bitsets for the kernel probe
# path, keyed by content fingerprint so repeated expand_group calls don't
# re-upload adjacency
_DEVICE_BITS_CACHE: Dict[str, tuple] = {}
_DEVICE_BITS_CAPACITY = 8


def _device_bits(g: GraphStore, adj: np.ndarray, adj_key: str) -> tuple:
    key = f"{g.fingerprint}:{adj_key}"
    ent = _DEVICE_BITS_CACHE.pop(key, None)     # LRU: re-insert on hit
    if ent is None:
        w = bitset.num_words(g.n)
        ent = (jnp.asarray(adj), jnp.asarray(bitset.eye_table(g.n)),
               jnp.full((1, w), 0xFFFFFFFF, jnp.uint32))
        while len(_DEVICE_BITS_CACHE) >= _DEVICE_BITS_CAPACITY:
            _DEVICE_BITS_CACHE.pop(next(iter(_DEVICE_BITS_CACHE)))
    _DEVICE_BITS_CACHE[key] = ent
    return ent


def _edge_probe(g: GraphStore, u: np.ndarray, v: np.ndarray,
                use_pallas: bool = False,
                interpret: Optional[bool] = None,
                predicate: Optional[LabelPredicate] = None) -> np.ndarray:
    """Batched edge-existence probe: ``out[e] = (u[e], v[e]) in E``.

    Reference path: numpy word-gather into the packed adjacency.  Kernel
    path: ``popcount(adj[u] & eye[v] & ones)`` via the masked-intersection
    kernel (rows = adjacency rows, row mask = one-hot target bitsets,
    single all-ones column).  Rows are padded to the next power of two so
    ragged embedding batches reuse a handful of kernel traces.

    Under a predicate with ``edge_any_of``, both paths probe the
    type-restricted adjacency (DESIGN.md §12) — the restriction rides the
    same packed layout, so the kernel call shape is unchanged.
    """
    if predicate is not None and predicate.edge_any_of is not None:
        adj = predicate.adjacency(g)
        adj_key = ",".join(map(str, predicate.edge_any_of))
    else:
        adj, adj_key = g.adj_bits, ""
    if not use_pallas or len(u) == 0:
        return _has_edge_vec(adj, u, v)
    from repro.kernels import ops as kops
    adj_d, eye_d, ones = _device_bits(g, adj, adj_key)
    e = len(u)
    ep = 1 << max(3, (e - 1).bit_length())
    up = np.zeros(ep, np.int64)
    vp = np.zeros(ep, np.int64)
    up[:e], vp[:e] = u, v
    counts = kops.masked_intersect(adj_d[jnp.asarray(up)], ones,
                                   eye_d[jnp.asarray(vp)],
                                   interpret=interpret)
    return np.asarray(counts[:e, 0]) > 0


def _gather_neighbors(g: GraphStore, vs: np.ndarray):
    """All (row, neighbor, CSR slot) triples for sources ``vs`` — fully
    vectorized CSR.  The slot index maps each pair back to its
    ``edge_labels`` entry (edge-type filtering)."""
    counts = g.degrees[vs].astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return (np.zeros(0, np.int64), np.zeros(0, np.int32),
                np.zeros(0, np.int64))
    rows = np.repeat(np.arange(len(vs), dtype=np.int64), counts)
    starts = g.indptr[vs].astype(np.int64)
    offset = np.arange(total, dtype=np.int64) - \
        np.repeat(np.cumsum(counts) - counts, counts)
    slots = np.repeat(starts, counts) + offset
    return rows, g.indices[slots], slots


def seed_groups(g: GraphStore,
                predicate: Optional[LabelPredicate] = None
                ) -> Dict[Code, PatternGroup]:
    """All one-edge groups with minimal codes (paper Fig. 5 step 1):
    one embedding per *directed* edge whose code ``(0,1,la,lb)`` is minimal
    (``la <= lb``; both orientations when ``la == lb``).

    A predicate filters the seed edge list up front in every mode — the
    seed pass is host-side either way; the pushdown-vs-post distinction
    concerns the per-step extension hot path (:func:`expand_group`).
    """
    assert g.labels is not None
    if predicate is not None:
        predicate.validate(g, "pattern")
    ea = g.edge_array                       # both directions present
    la = g.labels[ea[:, 0]]
    lb = g.labels[ea[:, 1]]
    keep = la <= lb
    if predicate is not None:
        vm = predicate.vertex_mask(g)
        if vm is not None:
            keep &= vm[ea[:, 0]] & vm[ea[:, 1]]
        em = predicate.edge_mask_csr(g)     # aligned with edge_array rows
        if em is not None:
            keep &= em
    groups: Dict[Code, PatternGroup] = {}
    for key in np.unique(np.stack([la[keep], lb[keep]], 1), axis=0):
        m = keep & (la == key[0]) & (lb == key[1])
        code = ((0, 1, int(key[0]), int(key[1])),)
        groups[code] = PatternGroup(code, ea[m].astype(np.int32))
    return groups


def expand_group(g: GraphStore, group: PatternGroup,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 predicate: Optional[LabelPredicate] = None,
                 label_filter: str = "pushdown"
                 ) -> Tuple[Dict[Code, PatternGroup], int]:
    """Pattern-oriented expansion: extend every embedding by one
    rightmost-path edge; child groups keyed by (minimal) code.

    ``use_pallas`` routes the rightmost-path edge-existence checks through
    the masked-intersection kernel (:func:`_edge_probe`); results are
    byte-identical to the numpy reference path.

    Label-constrained mining (DESIGN.md §12): ``edge_any_of`` restricts
    both the forward CSR gather and the backward bitset probes to allowed
    edge types (structural, every mode).  ``vertex_any_of`` has two
    placements: ``label_filter="pushdown"`` drops disallowed-label
    neighbors *before* child embeddings are materialized (the paper's
    proactive pruning — they never count as candidates), while ``"post"``
    materializes them, counts them, and then filters — the host-side
    baseline.  Child groups and supports are identical in both modes;
    only ``candidates_created`` (and the work it measures) differs.

    Returns (children, candidates_created) — the latter is the paper's cost
    metric (embeddings materialized, pre minimality filtering).
    """
    assert label_filter in LABEL_FILTERS, label_filter
    vmask = predicate.vertex_mask(g) if predicate is not None else None
    emask = predicate.edge_mask_csr(g) if predicate is not None else None
    code, emb = group.code, group.embeddings
    nv = emb.shape[1]
    rmpath = code_rightmost_path(code)
    vlabels = code_vertex_labels(code)
    p_adj = _pattern_adj(code)
    right = rmpath[-1]
    created = 0
    children: Dict[Code, PatternGroup] = {}

    def _add(child_code: Code, child_emb: np.ndarray):
        nonlocal created
        created += len(child_emb)
        if len(child_emb) == 0 or not is_min_code(child_code):
            return
        child_emb = np.unique(child_emb, axis=0)
        if child_code in children:
            prev = children[child_code].embeddings
            children[child_code] = PatternGroup(
                child_code, np.unique(np.concatenate([prev, child_emb]), axis=0))
        else:
            children[child_code] = PatternGroup(child_code, child_emb)

    # --- backward extensions: rightmost vertex -> earlier rmpath vertex.
    # All candidate targets share one batched probe call (E × |targets|
    # pairs) instead of one call per rightmost-path vertex.
    back_js = [j for j in rmpath[:-1] if j not in p_adj[right]]
    if back_js and len(emb):
        hits = _edge_probe(
            g, np.tile(emb[:, right], len(back_js)),
            np.concatenate([emb[:, j] for j in back_js]),
            use_pallas, interpret,
            predicate=predicate).reshape(len(back_js), len(emb))
        for row, j in enumerate(back_js):
            child_code = tuple(code) + \
                ((right, j, vlabels[right], vlabels[j]),)
            _add(child_code, emb[hits[row]])

    # --- forward extensions from every rightmost-path vertex
    allowed_lw = (set(predicate.vertex_any_of)
                  if vmask is not None else None)
    for i in rmpath:
        rows, nbr, slots = _gather_neighbors(g, emb[:, i])
        if len(rows) == 0:
            continue
        if emask is not None:             # edge-type restriction: structural
            keep = emask[slots]
            rows, nbr = rows[keep], nbr[keep]
        if vmask is not None and label_filter == "pushdown":
            # predicate pushdown: disallowed-label neighbors never become
            # embeddings (and never count as candidates)
            keep = vmask[nbr]
            rows, nbr = rows[keep], nbr[keep]
        # exclude neighbors already used by the embedding
        if len(rows) == 0:
            continue
        used = (emb[rows] == nbr[:, None]).any(axis=1)
        rows, nbr = rows[~used], nbr[~used]
        if len(rows) == 0:
            continue
        nl = g.labels[nbr]
        for lw in np.unique(nl):
            m = nl == lw
            if allowed_lw is not None and int(lw) not in allowed_lw:
                # post mode only (pushdown filtered above): the host-side
                # baseline materializes these embeddings, counts them as
                # candidates, then drops them
                created += int(m.sum())
                continue
            child_code = tuple(code) + ((i, nv, vlabels[i], int(lw)),)
            child_emb = np.concatenate(
                [emb[rows[m]], nbr[m, None].astype(np.int32)], axis=1)
            _add(child_code, child_emb)

    return children, created
