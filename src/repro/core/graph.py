"""Graph store: the data-graph substrate shared by the Nuri engine and the
GNN workloads.

Holds three synchronized views of an undirected (optionally labeled) graph:

* **CSR** (``indptr``/``indices``) — for neighbor iteration, sampling, and
  ``segment_sum`` message passing,
* **edge list** (``src``/``dst``, each undirected edge twice) — for GNN
  scatter kernels,
* **bitset adjacency** (``adj_bits [N, W] uint32``) — for the discovery
  engine's vectorized set intersections.

All arrays are numpy on the host; :meth:`device_arrays` returns the jnp views
the engine closes over.
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import cached_property
from typing import Optional

import numpy as np
import jax.numpy as jnp

from . import bitset


@dataclasses.dataclass(frozen=True)
class GraphStore:
    n: int                               # number of vertices
    indptr: np.ndarray                   # [N+1] int32 CSR row pointers
    indices: np.ndarray                  # [M2] int32 CSR column indices (sorted per row)
    labels: Optional[np.ndarray] = None  # [N] int32 vertex labels (None = unlabeled)

    # ---------------------------------------------------------------- build
    @staticmethod
    def from_edges(n: int, edges: np.ndarray,
                   labels: Optional[np.ndarray] = None) -> "GraphStore":
        """Build from an undirected edge array [M, 2]; dedupes + drops loops."""
        edges = np.asarray(edges, np.int64).reshape(-1, 2)
        edges = edges[edges[:, 0] != edges[:, 1]]
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        key = lo * n + hi
        _, first = np.unique(key, return_index=True)
        lo, hi = lo[first], hi[first]
        src = np.concatenate([lo, hi])
        dst = np.concatenate([hi, lo])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        indptr = np.zeros(n + 1, np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return GraphStore(
            n=n,
            indptr=indptr.astype(np.int32),
            indices=dst.astype(np.int32),
            labels=None if labels is None else np.asarray(labels, np.int32),
        )

    # ------------------------------------------------------------ properties
    @property
    def num_edges(self) -> int:
        """Number of undirected edges."""
        return len(self.indices) // 2

    @cached_property
    def fingerprint(self) -> str:
        """Deterministic content hash of the graph (topology + labels).

        Keys the service result cache (DESIGN.md §9): two GraphStores with
        identical CSR and labels hash identically regardless of how they
        were built.
        """
        h = hashlib.sha256()
        h.update(np.int64(self.n).tobytes())
        h.update(np.ascontiguousarray(self.indptr, np.int64).tobytes())
        h.update(np.ascontiguousarray(self.indices, np.int64).tobytes())
        if self.labels is not None:
            h.update(np.ascontiguousarray(self.labels, np.int64).tobytes())
        return h.hexdigest()

    @cached_property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @cached_property
    def adj_bits(self) -> np.ndarray:
        """[N, W] uint32 packed adjacency rows."""
        w = bitset.num_words(self.n)
        out = np.zeros((self.n, w), np.uint32)
        src = np.repeat(np.arange(self.n), np.diff(self.indptr))
        dst = self.indices.astype(np.int64)
        np.bitwise_or.at(
            out, (src, dst // 32), np.uint32(1) << (dst % 32).astype(np.uint32))
        return out

    @cached_property
    def edge_array(self) -> np.ndarray:
        """[M2, 2] directed edge list (each undirected edge both ways)."""
        src = np.repeat(np.arange(self.n, dtype=np.int32),
                        np.diff(self.indptr))
        return np.stack([src, self.indices], axis=1)

    @cached_property
    def label_bits(self) -> Optional[np.ndarray]:
        """[L, W] uint32: bitset of vertices per label."""
        if self.labels is None:
            return None
        n_labels = int(self.labels.max()) + 1
        return np.stack([
            bitset.from_indices(np.nonzero(self.labels == l)[0], self.n)
            for l in range(n_labels)])

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        row = self.neighbors(u)
        i = np.searchsorted(row, v)
        return bool(i < len(row) and row[i] == v)

    # ------------------------------------------------------------ device view
    def device_arrays(self) -> dict:
        d = dict(
            adj_bits=jnp.asarray(self.adj_bits),
            gt_bits=jnp.asarray(bitset.lt_mask_table(self.n)),
            degrees=jnp.asarray(self.degrees),
            indptr=jnp.asarray(self.indptr),
            indices=jnp.asarray(self.indices),
        )
        if self.labels is not None:
            d["labels"] = jnp.asarray(self.labels)
            d["label_bits"] = jnp.asarray(self.label_bits)
        return d

    # --------------------------------------------------------------- queries
    def bfs_hops(self, source: int, max_hops: int) -> np.ndarray:
        """[N] hop distance from ``source`` (-1 if > max_hops / unreachable)."""
        dist = np.full(self.n, -1, np.int32)
        dist[source] = 0
        frontier = np.array([source])
        for h in range(1, max_hops + 1):
            nxt = np.unique(np.concatenate(
                [self.neighbors(v) for v in frontier])) if len(frontier) else \
                np.empty(0, np.int32)
            nxt = nxt[dist[nxt] < 0]
            dist[nxt] = h
            frontier = nxt
            if not len(frontier):
                break
        return dist
