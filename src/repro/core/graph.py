"""Graph store: the data-graph substrate shared by the Nuri engine and the
GNN workloads.

Holds three synchronized views of an undirected (optionally labeled) graph:

* **CSR** (``indptr``/``indices``) — for neighbor iteration, sampling, and
  ``segment_sum`` message passing,
* **edge list** (``src``/``dst``, each undirected edge twice) — for GNN
  scatter kernels,
* **bitset adjacency** (``adj_bits [N, W] uint32``) — for the discovery
  engine's vectorized set intersections.

Attributed graphs carry two optional label layers (DESIGN.md §12): per-
vertex labels (packed per-label bitsets in :attr:`GraphStore.label_bits`)
and per-edge types (per-type packed adjacency planes in
:attr:`GraphStore.etype_adj_bits`) — both in the same ``[.., W] uint32``
word layout as :mod:`repro.core.bitset`, so label predicates compose with
the masked-intersection kernel by bitwise AND.

All arrays are numpy on the host; :meth:`device_arrays` returns the jnp views
the engine closes over.
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import cached_property
from typing import Optional

import numpy as np
import jax.numpy as jnp

from . import bitset


@dataclasses.dataclass(frozen=True)
class GraphStore:
    n: int                               # number of vertices
    indptr: np.ndarray                   # [N+1] int32 CSR row pointers
    indices: np.ndarray                  # [M2] int32 CSR column indices (sorted per row)
    labels: Optional[np.ndarray] = None  # [N] int32 vertex labels (None = unlabeled)
    # [M2] int32 edge type per directed CSR slot (aligned with ``indices``;
    # both directions of an undirected edge carry the same type) — the
    # attributed-graph edge layer (DESIGN.md §12); None = untyped edges
    edge_labels: Optional[np.ndarray] = None

    # ---------------------------------------------------------------- build
    @staticmethod
    def from_edges(n: int, edges: np.ndarray,
                   labels: Optional[np.ndarray] = None,
                   edge_labels: Optional[np.ndarray] = None) -> "GraphStore":
        """Build from an undirected edge array [M, 2]; dedupes + drops loops.

        ``edge_labels`` is one int type per input edge row; on duplicate
        edges the first occurrence's type wins (deterministic given input
        order).
        """
        edges = np.asarray(edges, np.int64).reshape(-1, 2)
        if edge_labels is not None:
            edge_labels = np.asarray(edge_labels, np.int64).reshape(-1)
            if len(edge_labels) != len(edges):
                raise ValueError(
                    f"edge_labels has {len(edge_labels)} entries for "
                    f"{len(edges)} edges")
        keep = edges[:, 0] != edges[:, 1]
        edges = edges[keep]
        if edge_labels is not None:
            edge_labels = edge_labels[keep]
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        key = lo * n + hi
        _, first = np.unique(key, return_index=True)
        lo, hi = lo[first], hi[first]
        src = np.concatenate([lo, hi])
        dst = np.concatenate([hi, lo])
        lab = (np.concatenate([edge_labels[first], edge_labels[first]])
               if edge_labels is not None else None)
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        indptr = np.zeros(n + 1, np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return GraphStore(
            n=n,
            indptr=indptr.astype(np.int32),
            indices=dst.astype(np.int32),
            labels=None if labels is None else np.asarray(labels, np.int32),
            edge_labels=None if lab is None else lab[order].astype(np.int32),
        )

    # ------------------------------------------------------------ properties
    @property
    def num_edges(self) -> int:
        """Number of undirected edges."""
        return len(self.indices) // 2

    @cached_property
    def fingerprint(self) -> str:
        """Deterministic content hash of the graph (topology + vertex and
        edge labels).

        Keys the service result cache (DESIGN.md §9): two GraphStores with
        identical CSR and labels hash identically regardless of how they
        were built.  The unlabeled/untyped hashes are unchanged from before
        the attributed layers existed (the extra blocks are appended only
        when present).
        """
        h = hashlib.sha256()
        h.update(np.int64(self.n).tobytes())
        h.update(np.ascontiguousarray(self.indptr, np.int64).tobytes())
        h.update(np.ascontiguousarray(self.indices, np.int64).tobytes())
        if self.labels is not None:
            h.update(np.ascontiguousarray(self.labels, np.int64).tobytes())
        if self.edge_labels is not None:
            h.update(b"etypes")
            h.update(np.ascontiguousarray(
                self.edge_labels, np.int64).tobytes())
        return h.hexdigest()

    @property
    def n_labels(self) -> int:
        """Number of distinct vertex-label values (0 = unlabeled)."""
        return 0 if self.labels is None else int(self.labels.max()) + 1

    @property
    def n_edge_labels(self) -> int:
        """Number of distinct edge-type values (0 = untyped)."""
        return 0 if self.edge_labels is None else \
            int(self.edge_labels.max()) + 1

    @cached_property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @cached_property
    def adj_bits(self) -> np.ndarray:
        """[N, W] uint32 packed adjacency rows."""
        w = bitset.num_words(self.n)
        out = np.zeros((self.n, w), np.uint32)
        src = np.repeat(np.arange(self.n), np.diff(self.indptr))
        dst = self.indices.astype(np.int64)
        np.bitwise_or.at(
            out, (src, dst // 32), np.uint32(1) << (dst % 32).astype(np.uint32))
        return out

    @cached_property
    def edge_array(self) -> np.ndarray:
        """[M2, 2] directed edge list (each undirected edge both ways)."""
        src = np.repeat(np.arange(self.n, dtype=np.int32),
                        np.diff(self.indptr))
        return np.stack([src, self.indices], axis=1)

    @cached_property
    def label_bits(self) -> Optional[np.ndarray]:
        """[L, W] uint32: bitset of vertices per label."""
        if self.labels is None:
            return None
        return np.stack([
            bitset.from_indices(np.nonzero(self.labels == l)[0], self.n)
            for l in range(self.n_labels)])

    @cached_property
    def etype_adj_bits(self) -> Optional[np.ndarray]:
        """[T, N, W] uint32: per-edge-type packed adjacency — row ``v`` of
        plane ``t`` is the set of neighbors reached from ``v`` over an edge
        of type ``t``.  ORing planes over an allowed-type set yields the
        restricted adjacency a label predicate's ``edge_any_of`` runs on
        (:meth:`repro.core.labels.LabelPredicate.adjacency`); the OR over
        *all* planes is exactly :attr:`adj_bits`.
        """
        if self.edge_labels is None:
            return None
        w = bitset.num_words(self.n)
        out = np.zeros((self.n_edge_labels, self.n, w), np.uint32)
        src = np.repeat(np.arange(self.n), np.diff(self.indptr))
        dst = self.indices.astype(np.int64)
        et = self.edge_labels.astype(np.int64)
        np.bitwise_or.at(
            out, (et, src, dst // 32),
            np.uint32(1) << (dst % 32).astype(np.uint32))
        return out

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        row = self.neighbors(u)
        i = np.searchsorted(row, v)
        return bool(i < len(row) and row[i] == v)

    # ------------------------------------------------------------ device view
    def device_arrays(self) -> dict:
        d = dict(
            adj_bits=jnp.asarray(self.adj_bits),
            gt_bits=jnp.asarray(bitset.lt_mask_table(self.n)),
            degrees=jnp.asarray(self.degrees),
            indptr=jnp.asarray(self.indptr),
            indices=jnp.asarray(self.indices),
        )
        if self.labels is not None:
            d["labels"] = jnp.asarray(self.labels)
            d["label_bits"] = jnp.asarray(self.label_bits)
        return d

    # --------------------------------------------------------------- queries
    def bfs_hops(self, source: int, max_hops: int) -> np.ndarray:
        """[N] hop distance from ``source`` (-1 if > max_hops / unreachable)."""
        dist = np.full(self.n, -1, np.int32)
        dist[source] = 0
        frontier = np.array([source])
        for h in range(1, max_hops + 1):
            nxt = np.unique(np.concatenate(
                [self.neighbors(v) for v in frontier])) if len(frontier) else \
                np.empty(0, np.int32)
            nxt = nxt[dist[nxt] < 0]
            dist[nxt] = h
            frontier = nxt
            if not len(frontier):
                break
        return dist
