"""User-facing computational model — the paper's Table-1 API, batched.

The paper's five user functions map onto a :class:`SubgraphComputation`:

=====================  =========================================================
paper (Table 1)        here
=====================  =========================================================
``expandable(s, δ)``   fused into ``score_children`` (invalid actions → ``NEG``)
``priority(s)``        the int32 key returned by ``score_children`` /
                       ``init_frontier`` (queue ordering)
``relevant(s)``        ``result_key`` (``NEG`` when not relevant)
``dominated(s, s')``   ``upper_bound`` compared against the k-th result key
``key(s)``             aggregate engine only (:mod:`repro.core.aggregate`)
=====================  =========================================================

Two key spaces exist, exactly as in the paper: the **priority** key orders the
queue (e.g. lexicographic ``(|V_s|, |P_s|)`` for cliques) and the **result**
key ranks the result set (e.g. clique size).  ``upper_bound`` lives in result
space: it must over-approximate the best result key reachable from a state.

API contract (property-tested in ``tests/test_engine_properties.py``):

* ``upper_bound(s) >= result_key(s)`` for every state;
* ``upper_bound(s) >= upper_bound(child)`` for every child of ``s``
  (anti-monotonicity — what makes threshold pruning sound).

States are fixed-width ``int32`` vectors; actions are integers in
``[0, num_actions)``.  ``score_children`` performs *targeted expansion*: it
returns ``NEG`` priority for any (state, action) that must not be created,
so irrelevant subgraphs are never materialized (contrast: Arabesque's
exhaustive expansion + post-filter, implemented in
:mod:`repro.core.exhaustive` as the baseline).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

NEG = jnp.iinfo(jnp.int32).min  # "-inf" for int32 keys


@dataclasses.dataclass(frozen=True)
class SubgraphComputation:
    """A batched top-k subgraph-discovery computation."""

    name: str
    state_width: int   # S: int32 words per subgraph state
    num_actions: int   # A: action space (e.g. N vertices)

    # () -> (states [n0, S], prio [n0], ub [n0])
    init_frontier: Callable[[], Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]

    # states [B, S] -> (child_prio [B, A], child_ub [B, A]); NEG = not expandable
    score_children: Callable[[jnp.ndarray],
                             Tuple[jnp.ndarray, jnp.ndarray]]

    # (parent_states [M, S], actions [M]) -> child states [M, S]
    materialize: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]

    # states [B, S] -> result keys [B] (NEG when not relevant)
    result_key: Callable[[jnp.ndarray], jnp.ndarray]

    # states [B, S] -> result-space upper bound [B]
    upper_bound: Callable[[jnp.ndarray], jnp.ndarray]

    # pretty-printer for result states (host-side)
    describe: Optional[Callable] = None

    def __post_init__(self):
        if self.state_width <= 0:
            raise ValueError(
                f"{self.name}: state_width must be positive, "
                f"got {self.state_width}")
        if self.num_actions <= 0:
            raise ValueError(
                f"{self.name}: num_actions must be positive, "
                f"got {self.num_actions}")


def from_pointwise(name: str,
                   state_width: int,
                   num_actions: int,
                   init_frontier,
                   expandable,       # (state [S], action) -> bool
                   child_priority,   # (state [S], action) -> int32
                   child_ub,         # (state [S], action) -> int32
                   materialize_one,  # (state [S], action) -> state [S]
                   relevant,         # (state [S]) -> bool
                   result_key_one,   # (state [S]) -> int32
                   upper_bound_one,  # (state [S]) -> int32
                   describe=None) -> SubgraphComputation:
    """Succinct per-subgraph API (the paper's Listing-1 style), vmapped.

    Users write scalar functions over a single state; this adapter builds the
    batched computation via ``jax.vmap``.  The fused batched path (e.g.
    :mod:`repro.core.clique`) is preferred for hot computations.
    """
    actions = jnp.arange(num_actions, dtype=jnp.int32)

    def score_children(states):
        def per_state(s):
            def per_action(a):
                ok = expandable(s, a)
                return (jnp.where(ok, child_priority(s, a), NEG),
                        jnp.where(ok, child_ub(s, a), NEG))
            return jax.vmap(per_action)(actions)
        return jax.vmap(per_state)(states)

    def materialize(states, acts):
        return jax.vmap(materialize_one)(states, acts)

    def result_key(states):
        def one(s):
            return jnp.where(relevant(s), result_key_one(s), NEG)
        return jax.vmap(one)(states)

    def upper_bound(states):
        return jax.vmap(upper_bound_one)(states)

    return SubgraphComputation(
        name=name, state_width=state_width, num_actions=num_actions,
        init_frontier=init_frontier, score_children=score_children,
        materialize=materialize, result_key=result_key,
        upper_bound=upper_bound, describe=describe)
