"""Packed-bitset algebra in JAX.

Subgraph states in the Nuri engine are fixed-width bitsets packed into
``uint32`` words (``W = ceil(N / 32)`` words for an N-vertex graph).  All
operations are elementwise / reduction ops that map directly onto the TPU
VPU; the hot combination (AND + population count) is also provided as a
Pallas kernel in :mod:`repro.kernels.frontier_expand`.

States are routinely stored bit-cast to ``int32`` (the engine's generic
state dtype); use :func:`to_i32` / :func:`to_u32` at the boundary.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

WORD_BITS = 32


def num_words(n_bits: int) -> int:
    """Number of uint32 words needed for ``n_bits`` bits."""
    return (int(n_bits) + WORD_BITS - 1) // WORD_BITS


def to_i32(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def to_u32(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(x, jnp.uint32)


def zeros(shape_prefix, n_bits: int) -> jnp.ndarray:
    return jnp.zeros(tuple(shape_prefix) + (num_words(n_bits),), jnp.uint32)


def from_indices(indices, n_bits: int) -> np.ndarray:
    """Host-side: build a packed bitset (numpy) from an index list."""
    w = num_words(n_bits)
    out = np.zeros((w,), np.uint32)
    idx = np.asarray(indices, np.int64)
    if idx.size:
        np.bitwise_or.at(out, idx // WORD_BITS,
                         (np.uint32(1) << (idx % WORD_BITS).astype(np.uint32)))
    return out


def from_bool(mask: np.ndarray) -> np.ndarray:
    """Host-side: pack a boolean vector [..., N] into [..., W] uint32."""
    mask = np.asarray(mask, bool)
    n = mask.shape[-1]
    w = num_words(n)
    pad = w * WORD_BITS - n
    if pad:
        mask = np.concatenate(
            [mask, np.zeros(mask.shape[:-1] + (pad,), bool)], axis=-1)
    bits = mask.reshape(mask.shape[:-1] + (w, WORD_BITS)).astype(np.uint32)
    shifts = (np.uint32(1) << np.arange(WORD_BITS, dtype=np.uint32))
    return (bits * shifts).sum(axis=-1).astype(np.uint32)


def to_bool(bitset: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Unpack [..., W] uint32 into a boolean [..., n_bits] array."""
    w = bitset.shape[-1]
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (bitset[..., :, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(bitset.shape[:-1] + (w * WORD_BITS,))
    return flat[..., :n_bits].astype(bool)


def popcount(bitset: jnp.ndarray, axis=-1) -> jnp.ndarray:
    """Total number of set bits along ``axis`` (int32)."""
    return jnp.sum(jax.lax.population_count(bitset).astype(jnp.int32),
                   axis=axis)


def get_bit(bitset: jnp.ndarray, idx) -> jnp.ndarray:
    """Test bit ``idx`` (int array broadcastable to batch) -> bool."""
    idx = jnp.asarray(idx)
    word = jnp.take_along_axis(
        bitset, (idx // WORD_BITS)[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    return ((word >> (idx % WORD_BITS).astype(jnp.uint32)) & 1).astype(bool)


def set_bit(bitset: jnp.ndarray, idx) -> jnp.ndarray:
    """Return a copy of ``bitset`` with bit ``idx`` set (batched)."""
    idx = jnp.asarray(idx)
    word_idx = (idx // WORD_BITS).astype(jnp.int32)
    bit = (jnp.uint32(1) << (idx % WORD_BITS).astype(jnp.uint32))
    w = bitset.shape[-1]
    onehot = (jnp.arange(w, dtype=jnp.int32) == word_idx[..., None])
    return bitset | jnp.where(onehot, bit[..., None], jnp.uint32(0))


def lt_mask_table(n: int) -> np.ndarray:
    """Host-side table ``gt[v]`` = bitset of {u : u > v}, shape [n, W].

    Used for canonical (duplicate-free) clique expansion: the candidate set
    of ``s ∪ {v}`` is ``P_s ∩ N(v) ∩ gt[v]``.
    """
    w = num_words(n)
    u = np.arange(w * WORD_BITS)[None, :]
    v = np.arange(n)[:, None]
    mask = (u > v) & (u < n)
    return from_bool(mask)


def eye_table(n: int) -> np.ndarray:
    """Host-side identity table ``eye[v]`` = bitset containing only ``v``,
    shape [n, W].

    Used as the column operand of the masked-intersection kernel to turn
    popcounts into membership probes: ``popcount(m & eye[v])`` is bit ``v``
    of ``m`` (docs/KERNELS.md).
    """
    w = num_words(n)
    out = np.zeros((n, w), np.uint32)
    v = np.arange(n)
    out[v, v // WORD_BITS] = np.uint32(1) << (v % WORD_BITS).astype(np.uint32)
    return out


def first_set_bit(bitset: jnp.ndarray) -> jnp.ndarray:
    """Index of the lowest set bit, or -1 if empty.  Batched over leading dims."""
    w = bitset.shape[-1]
    # lowest set bit per word
    low = bitset & (~bitset + jnp.uint32(1))
    # log2 of an exact power of two via popcount(x - 1)
    bit_in_word = jax.lax.population_count(low - jnp.uint32(1)).astype(jnp.int32)
    has = (bitset != 0)
    word_idx = jnp.argmax(has, axis=-1).astype(jnp.int32)
    any_set = jnp.any(has, axis=-1)
    sel = jnp.take_along_axis(bit_in_word, word_idx[..., None], axis=-1)[..., 0]
    return jnp.where(any_set, word_idx * WORD_BITS + sel, -1)
