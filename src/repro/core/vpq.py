"""Virtual priority queue — the paper's on-disk subgraph management (§5).

The device pool (HBM in production) holds the high-priority states; when it
overflows, the lowest-priority entries exit the jitted step as a fixed-size
block and are spilled here as **sorted runs** — exactly the paper's design:

* spill creates a run sorted in decreasing priority ("stores the others on
  disk in order of decreasing priority");
* dequeue/refill performs a **buffered k-way merge** over run heads
  (external-merge-sort style, "a small number of disk seeks"):
  each run keeps an in-memory block buffer; a blockwise merge over the
  buffers yields the globally highest entries.  The merge is vectorized
  (DESIGN.md §13): instead of one heap pop per entry, every live run's
  buffered block is pulled at once, concatenated, and stably argsorted by
  descending priority; the *safe prefix* — entries no unbuffered tail can
  outrank — is consumed in bulk and per-run cursors advance by block.  The
  emitted order is byte-identical to the entry-at-a-time heap merge
  (priority descending, ties by run index then within-run position).

Backends: ``host`` (numpy arrays in host DRAM — the HBM:DRAM ratio on a TPU
host mirrors the paper's DRAM:disk ratio) and ``disk`` (memory-mapped ``.npy``
runs with block reads — the literal reproduction used by
``benchmarks/bench_vpq.py`` for Figure 19).

Refill also applies **late dominance pruning**: entries whose stored upper
bound has fallen below the current k-th-result threshold are dropped during
the merge instead of being shipped back to the device; drops are counted in
:attr:`VirtualPriorityQueue.total_late_pruned` so pruning effectiveness
(a paper metric) is auditable end to end (``EngineResult.late_pruned``,
service response ``stats``).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import List, Optional

import numpy as np

from repro.obs import NOOP

NEG = np.iinfo(np.int32).min


def _link_or_copy(src: str, dst: str) -> None:
    """Reference ``src`` at ``dst`` without copying data: a hardlink where
    the filesystem allows it (same device — the normal case for a
    checkpoint dir next to the spill dir), byte copy as the fallback.
    Spill-run ``.npy`` files are write-once immutable, so a link is as
    good as a copy — and deleting either name leaves the other readable.
    """
    if os.path.exists(dst):
        os.remove(dst)
    try:
        os.link(src, dst)
    except OSError:
        shutil.copy2(src, dst)


class _Run:
    """One sorted spill run with buffered sequential reads."""

    def __init__(self, states, prio, ub, backend: str, spill_dir: str,
                 run_id: int, buffer_size: int, obs=NOOP):
        self.n = len(prio)
        self.cursor = 0
        self.buffer_size = buffer_size
        self._buf_start = 0
        self._obs = obs
        if backend == "disk":
            t0 = time.perf_counter() if obs.enabled else 0.0
            self._paths = {}
            for name, arr in (("states", states), ("prio", prio), ("ub", ub)):
                path = os.path.join(spill_dir, f"run{run_id}_{name}.npy")
                np.save(path, arr)
                self._paths[name] = path
            if obs.enabled:
                obs.counter("vpq_disk_write_seconds_total").inc(
                    time.perf_counter() - t0)
            self._states = np.load(self._paths["states"], mmap_mode="r")
            self._prio = np.load(self._paths["prio"], mmap_mode="r")
            self._ub = np.load(self._paths["ub"], mmap_mode="r")
        else:
            self._paths = None
            self._states, self._prio, self._ub = states, prio, ub
        self._fill_buffer()

    def _fill_buffer(self):
        s, e = self.cursor, min(self.cursor + self.buffer_size, self.n)
        self._buf_start = s
        # one sequential block read per refill (the paper's buffering)
        time_it = self._paths is not None and self._obs.enabled
        t0 = time.perf_counter() if time_it else 0.0
        self._bstates = np.array(self._states[s:e])
        self._bprio = np.array(self._prio[s:e])
        self._bub = np.array(self._ub[s:e])
        if time_it:
            self._obs.counter("vpq_disk_read_seconds_total").inc(
                time.perf_counter() - t0)

    def head_prio(self) -> int:
        return int(self._bprio[self.cursor - self._buf_start])

    def pop(self):
        i = self.cursor - self._buf_start
        out = (self._bstates[i], int(self._bprio[i]), int(self._bub[i]))
        self.cursor += 1
        if self.cursor < self.n and self.cursor - self._buf_start >= \
                len(self._bprio):
            self._fill_buffer()
        return out

    # ------------------------------------------------- blockwise merge API
    def buffered(self):
        """The not-yet-consumed slice of the current buffer block
        (states, prio, ub) — sorted in decreasing priority like the run."""
        i = self.cursor - self._buf_start
        return self._bstates[i:], self._bprio[i:], self._bub[i:]

    @property
    def has_unbuffered(self) -> bool:
        """True when entries exist beyond the current buffer block."""
        return self._buf_start + len(self._bprio) < self.n

    @property
    def tail_prio(self) -> int:
        """Priority of the last (smallest) buffered entry — an upper bound
        on every unbuffered entry of this run (the run is sorted)."""
        return int(self._bprio[-1])

    def consume(self, c: int):
        """Advance the cursor by ``c`` consumed entries; refill the buffer
        with the next sequential block when the current one is spent."""
        self.cursor += c
        if self.cursor < self.n and self.cursor - self._buf_start >= \
                len(self._bprio):
            self._fill_buffer()

    @property
    def exhausted(self) -> bool:
        return self.cursor >= self.n

    def close(self):
        if self._paths:
            for p in self._paths.values():
                try:
                    os.remove(p)
                except OSError:
                    pass

    @classmethod
    def _restore(cls, n: int, cursor: int, buffer_size: int,
                 arrays=None, paths=None, obs=NOOP) -> "_Run":
        """Rebuild a run from checkpointed data: host arrays (already
        sliced to the unconsumed remainder, cursor 0) or disk file paths
        (full run files, cursor preserved).  Byte parity needs only the
        unconsumed suffix in original order — consumed entries are never
        compared again, and the blockwise merge's emitted order and
        consumption stop point are invariant to buffer alignment."""
        run = cls.__new__(cls)
        run.n = n
        run.cursor = cursor
        run.buffer_size = buffer_size
        run._buf_start = 0
        run._obs = obs
        if paths is not None:
            run._paths = dict(paths)
            run._states = np.load(paths["states"], mmap_mode="r")
            run._prio = np.load(paths["prio"], mmap_mode="r")
            run._ub = np.load(paths["ub"], mmap_mode="r")
        else:
            run._paths = None
            run._states, run._prio, run._ub = arrays
        run._fill_buffer()
        return run


class VirtualPriorityQueue:
    def __init__(self, state_width: int, backend: str = "host",
                 spill_dir: Optional[str] = None,
                 buffer_size: int = 8192,
                 run_flush_size: int = 1 << 15,
                 obs=None):
        assert backend in ("host", "disk", "none")
        self.state_width = state_width
        self.backend = backend
        self.buffer_size = buffer_size
        self.run_flush_size = run_flush_size
        # observability handles, resolved once (DESIGN.md §16)
        self.obs = obs if obs is not None else NOOP
        self._m_spilled = self.obs.counter(
            "vpq_spilled_entries_total", "entries spilled off-device")
        self._m_spill_bytes = self.obs.counter(
            "vpq_spill_bytes_total", "bytes pushed into spill runs")
        self._m_refill_bytes = self.obs.counter(
            "vpq_refill_bytes_total", "bytes returned by pop_chunk")
        self._m_late_pruned = self.obs.counter(
            "vpq_late_pruned_total", "dominated entries dropped on refill")
        self.runs: List[_Run] = []
        self._pending: List[tuple] = []   # (states, prio, ub) awaiting a run
        self._pending_n = 0
        self._run_id = 0
        self.total_spilled = 0
        self.total_late_pruned = 0        # dominated entries dropped on refill
        self._own_dir = spill_dir is None and backend == "disk"
        self.spill_dir = (tempfile.mkdtemp(prefix="nuri_vpq_")
                          if self._own_dir else spill_dir)
        if backend == "disk" and not self._own_dir:
            os.makedirs(self.spill_dir, exist_ok=True)

    def __len__(self) -> int:
        return self._pending_n + sum(r.n - r.cursor for r in self.runs)

    # ------------------------------------------------------------------ push
    def maybe_push(self, states: np.ndarray, prio: np.ndarray,
                   ub: np.ndarray):
        """Spill the valid (prio > NEG) entries of an overflow block."""
        mask = prio > NEG
        if not mask.any():
            return
        if self.backend == "none":
            raise RuntimeError(
                "priority pool overflow with spill disabled; raise "
                "pool_capacity or enable the virtual priority queue")
        states, prio, ub = states[mask], prio[mask], ub[mask]
        self.total_spilled += len(prio)
        self._m_spilled.inc(len(prio))
        self._m_spill_bytes.inc(states.nbytes + prio.nbytes + ub.nbytes)
        self._pending.append((states, prio, ub))
        self._pending_n += len(prio)
        if self._pending_n >= self.run_flush_size:
            self._flush_pending()

    def _flush_pending(self):
        if not self._pending:
            return
        states = np.concatenate([p[0] for p in self._pending])
        prio = np.concatenate([p[1] for p in self._pending])
        ub = np.concatenate([p[2] for p in self._pending])
        order = np.argsort(prio, kind="stable")[::-1]  # decreasing priority
        self.runs.append(_Run(
            np.ascontiguousarray(states[order]), prio[order], ub[order],
            self.backend, self.spill_dir, self._run_id, self.buffer_size,
            obs=self.obs))
        self._run_id += 1
        self._pending, self._pending_n = [], 0

    # ------------------------------------------------------------------- pop
    def pop_chunk(self, n: int, min_ub: int = NEG):
        """Return the globally top-``n`` surviving spilled entries
        (blockwise k-way run merge), dropping — and counting in
        ``total_late_pruned`` — entries whose upper bound is dominated by
        ``min_ub``.

        Vectorized merge: each round concatenates every live run's buffered
        block and stably argsorts by descending priority, so the global
        order is (priority desc, run index asc, within-run position asc) —
        exactly the order an entry-at-a-time heap merge with run-index
        tie-break produces.  An entry is *safe* to emit when no run's
        unbuffered tail could outrank it: with ``bar`` the largest buffered
        tail among runs that still have unbuffered data and ``rmin`` the
        smallest such run index at ``bar``, the safe region is
        ``prio > bar`` plus ``prio == bar`` from runs ``<= rmin`` (ties
        resolve by run index, and unbuffered entries of run ``r`` sort
        after its buffered ones).  That region is a prefix of the merged
        order and always contains the ``bar`` run's own buffered block, so
        every round either emits entries or exhausts a run — no per-entry
        Python loop, cursors advance in bulk.

        Consumption stops as soon as ``n`` entries survive pruning, leaving
        later entries (dominated or not) in their runs.
        """
        self._flush_pending()
        out_s, out_p, out_u = [], [], []
        need = n
        late_pruned0 = self.total_late_pruned
        live = [r for r in self.runs if not r.exhausted]
        while need > 0 and live:
            blocks = [r.buffered() for r in live]
            prio = np.concatenate([b[1] for b in blocks]).astype(np.int64)
            run_of = np.concatenate(
                [np.full(len(b[1]), j, np.int64)
                 for j, b in enumerate(blocks)])
            order = np.argsort(-prio, kind="stable")

            bar, rmin = None, None
            for j, r in enumerate(live):
                if r.has_unbuffered:
                    t = r.tail_prio
                    if bar is None or t > bar:
                        bar, rmin = t, j
            if bar is None:
                n_safe = len(order)
            else:
                p_sorted = prio[order]
                safe = (p_sorted > bar) | ((p_sorted == bar)
                                           & (run_of[order] <= rmin))
                # monotone prefix of the merged order; never empty — the
                # bar run's own buffered block is entirely inside it
                n_safe = int(np.searchsorted(~safe, True))
            take = order[:n_safe]

            ub = np.concatenate([b[2] for b in blocks])
            keep = ub[take] >= min_ub            # late dominance pruning
            cum = np.cumsum(keep)
            kept_total = int(cum[-1]) if n_safe else 0
            if kept_total >= need:               # stop at the need-th keeper
                stop = int(np.searchsorted(cum, need)) + 1
            else:
                stop = n_safe
            sel = take[:stop]
            kmask = keep[:stop]
            kept = sel[kmask]
            self.total_late_pruned += int(stop - kmask.sum())

            if len(kept):
                states = np.concatenate([b[0] for b in blocks])
                out_s.append(states[kept])
                out_p.append(prio[kept].astype(np.int32))
                out_u.append(ub[kept])
                need -= len(kept)
            for j, c in enumerate(np.bincount(run_of[sel],
                                              minlength=len(live))):
                if c:
                    live[j].consume(int(c))
            live = [r for r in live if not r.exhausted]
        # close exhausted runs as they drop out so the disk backend's .npy
        # run files are deleted immediately instead of leaking until close()
        keep_runs = []
        for r in self.runs:
            if r.exhausted:
                r.close()
            else:
                keep_runs.append(r)
        self.runs = keep_runs
        self._m_late_pruned.inc(self.total_late_pruned - late_pruned0)
        if not out_p:
            return (np.zeros((0, self.state_width), np.int32),
                    np.zeros((0,), np.int32), np.zeros((0,), np.int32))
        out = (np.concatenate(out_s).astype(np.int32),
               np.concatenate(out_p),
               np.concatenate(out_u).astype(np.int32))
        self._m_refill_bytes.inc(sum(a.nbytes for a in out))
        return out

    def close(self):
        for r in self.runs:
            r.close()
        self.runs = []
        if self._own_dir and self.spill_dir and os.path.isdir(self.spill_dir):
            shutil.rmtree(self.spill_dir, ignore_errors=True)

    # ------------------------------------------------------ snapshot/restore
    def snapshot(self, out_dir: str) -> dict:
        """Checkpoint the queue into ``out_dir``; returns the JSON manifest
        :meth:`restore` rebuilds from (DESIGN.md §15).

        Disk runs are *referenced, not copied*: the write-once ``.npy`` run
        files are hardlinked into ``out_dir``, so the snapshot costs no
        data movement and survives the live engine deleting its own link
        when the run exhausts.  Host runs save only the unconsumed
        ``[cursor:]`` suffix.  Pending (unflushed) fragments are saved as
        one concatenated triple — ``_flush_pending`` concatenates before
        sorting anyway, so the restored queue flushes to an identical run.
        Crucially the snapshot never flushes pending itself: forcing a run
        boundary here would change merge tie order versus the
        uninterrupted trajectory.
        """
        os.makedirs(out_dir, exist_ok=True)
        runs = []
        for j, r in enumerate(self.runs):
            if r._paths is not None:          # disk: link full files
                files = {}
                for name, src in r._paths.items():
                    fname = f"run{j}_{name}.npy"
                    _link_or_copy(src, os.path.join(out_dir, fname))
                    files[name] = fname
                runs.append({"kind": "disk", "n": int(r.n),
                             "cursor": int(r.cursor), "files": files})
            else:                             # host: save the remainder
                files = {}
                for name, arr in (("states", r._states), ("prio", r._prio),
                                  ("ub", r._ub)):
                    fname = f"run{j}_{name}.npy"
                    np.save(os.path.join(out_dir, fname),
                            np.asarray(arr[r.cursor:]))
                    files[name] = fname
                runs.append({"kind": "host", "n": int(r.n - r.cursor),
                             "cursor": 0, "files": files})
        pending = None
        if self._pending:
            pending = {}
            for i, name in enumerate(("states", "prio", "ub")):
                fname = f"pending_{name}.npy"
                np.save(os.path.join(out_dir, fname),
                        np.concatenate([p[i] for p in self._pending]))
                pending[name] = fname
        return {"state_width": self.state_width, "backend": self.backend,
                "buffer_size": self.buffer_size,
                "run_flush_size": self.run_flush_size,
                "run_id": self._run_id,
                "total_spilled": self.total_spilled,
                "total_late_pruned": self.total_late_pruned,
                "runs": runs, "pending": pending}

    @classmethod
    def restore(cls, manifest: dict, src_dir: str,
                spill_dir: Optional[str] = None,
                obs=None) -> "VirtualPriorityQueue":
        """Rebuild a queue from :meth:`snapshot` output.

        Disk runs are re-linked from the checkpoint into the *live* spill
        dir under fresh run ids and re-opened memory-mapped read-only; the
        restored queue owns (and deletes, on exhaust/close) its live
        links, while the checkpoint's own files stay intact — so the same
        step restores any number of times.
        """
        vpq = cls(state_width=int(manifest["state_width"]),
                  backend=manifest["backend"], spill_dir=spill_dir,
                  buffer_size=int(manifest["buffer_size"]),
                  run_flush_size=int(manifest["run_flush_size"]),
                  obs=obs)
        vpq.total_spilled = int(manifest["total_spilled"])
        vpq.total_late_pruned = int(manifest["total_late_pruned"])
        vpq._run_id = int(manifest["run_id"])
        for entry in manifest["runs"]:
            if entry["kind"] == "disk":
                rid = vpq._run_id
                vpq._run_id += 1
                paths = {}
                for name, fname in entry["files"].items():
                    dst = os.path.join(vpq.spill_dir, f"run{rid}_{name}.npy")
                    _link_or_copy(os.path.join(src_dir, fname), dst)
                    paths[name] = dst
                vpq.runs.append(_Run._restore(
                    int(entry["n"]), int(entry["cursor"]),
                    vpq.buffer_size, paths=paths, obs=vpq.obs))
            else:
                arrays = tuple(
                    np.load(os.path.join(src_dir, entry["files"][name]))
                    for name in ("states", "prio", "ub"))
                vpq.runs.append(_Run._restore(
                    int(entry["n"]), int(entry["cursor"]),
                    vpq.buffer_size, arrays=arrays, obs=vpq.obs))
        if manifest.get("pending"):
            arrays = tuple(
                np.load(os.path.join(src_dir, manifest["pending"][name]))
                for name in ("states", "prio", "ub"))
            if len(arrays[1]):
                vpq._pending.append(arrays)
                vpq._pending_n = len(arrays[1])
        return vpq
