"""Nuri core: the paper's computational models on JAX."""
