"""Maximum-WEIGHT clique discovery — written against the paper's succinct
per-subgraph API (:func:`repro.core.api.from_pointwise`), the Python analog
of the paper's Listing 1.

Demonstrates the Table-1 generality claim: a new top-k computation is four
scalar functions (expandable / priority / relevant+result / dominated); the
engine, batching, pruning, and VPQ come for free.

State layout (``S = 2W + 2``): V bitset, P bitset, weight(V), weight(P) —
the dominance bound ``w(V) + w(P)`` generalizes the CP cardinality bound.
Weights are positive integers.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import bitset
from .api import NEG, from_pointwise
from .graph import GraphStore


def make_weighted_clique_computation(graph: GraphStore,
                                     weights: np.ndarray):
    n = graph.n
    w = bitset.num_words(n)
    weights = np.asarray(weights, np.int32)
    assert (weights > 0).all()
    total = int(weights.sum())
    assert total < 2 ** 30, "int32 priority keys"
    S = 2 * w + 2

    adj = jnp.asarray(graph.adj_bits)
    gt = jnp.asarray(bitset.lt_mask_table(n))
    ext_mask = adj & gt
    wts = jnp.asarray(weights)
    # weight of a packed bitset via per-word unpack-dot
    wt_table = jnp.asarray(weights, jnp.int32)

    def _set_weight(bits):
        return jnp.sum(jnp.where(bitset.to_bool(bits, n), wt_table, 0))

    def init_frontier():
        v_bits = jnp.asarray(np.stack(
            [bitset.from_indices([v], n) for v in range(n)]))
        p_bits = ext_mask
        wv = wts
        wp = jax.vmap(_set_weight)(p_bits)
        states = jnp.concatenate(
            [bitset.to_i32(v_bits), bitset.to_i32(p_bits),
             wv[:, None], wp[:, None]], axis=-1)
        return states, wv + wp, wv + wp

    # ----- the paper's five user functions, scalar over one state --------
    def _unpack(s):
        return (bitset.to_u32(s[:w]), bitset.to_u32(s[w:2 * w]),
                s[2 * w], s[2 * w + 1])

    def expandable(s, a):
        _, p, _, _ = _unpack(s)
        return bitset.get_bit(p[None], jnp.asarray([a]))[0]

    def child_priority(s, a):
        _, p, wv, _ = _unpack(s)
        new_p = p & ext_mask[a]
        return wv + wts[a] + _set_weight(new_p)

    def child_ub(s, a):          # same space: weight is the result metric
        return child_priority(s, a)

    def materialize_one(s, a):
        v, p, wv, _ = _unpack(s)
        new_v = bitset.set_bit(v[None], jnp.asarray([a]))[0]
        new_p = p & ext_mask[a]
        return jnp.concatenate(
            [bitset.to_i32(new_v), bitset.to_i32(new_p),
             (wv + wts[a])[None], _set_weight(new_p)[None]])

    def relevant(s):
        return jnp.bool_(True)   # every expansion is a clique

    def result_key_one(s):
        return s[2 * w]          # w(V)

    def upper_bound_one(s):
        return s[2 * w] + s[2 * w + 1]   # w(V) + w(P): dominated() bound

    def describe(row):
        v_bits = np.asarray(row[:w]).view(np.uint32)
        return sorted(int(i) for i in np.nonzero(
            np.asarray(bitset.to_bool(jnp.asarray(v_bits), n)))[0])

    return from_pointwise(
        name="weighted-clique", state_width=S, num_actions=n,
        init_frontier=init_frontier, expandable=expandable,
        child_priority=child_priority, child_ub=child_ub,
        materialize_one=materialize_one, relevant=relevant,
        result_key_one=result_key_one, upper_bound_one=upper_bound_one,
        describe=describe)


def brute_force_max_weight_clique(graph: GraphStore, weights: np.ndarray):
    neigh = [set(map(int, graph.neighbors(v))) for v in range(graph.n)]
    best = [0, []]

    def rec(cur, cand, wsum):
        if wsum > best[0]:
            best[0], best[1] = wsum, list(cur)
        if wsum + sum(weights[u] for u in cand) <= best[0]:
            return
        for v in sorted(cand):
            rec(cur + [v], {u for u in cand if u > v and u in neigh[v]},
                wsum + int(weights[v]))

    rec([], set(range(graph.n)), 0)
    return best[0], sorted(best[1])
