"""Aggregate computational model — paper Algorithm 2 (top-k pattern mining).

Groups subgraphs by their grouping key (the pattern's minimal DFS code),
keeps a priority queue of *groups*, and applies the paper's user functions
at group granularity:

* ``key(s)``        — the minimal DFS code (pattern-oriented expansion),
* ``relevant(S)``   — pattern has exactly ``M`` edges,
* ``priority(S)``   — lexicographic ``(m(S), f(S))`` (edge count, support):
  larger patterns first, then more frequent ones (paper §3.3),
* ``dominated(S,S')`` — ``f(S) < f(S')`` — sound because minimum
  image-based support is anti-monotone [5].

Ragged group bookkeeping (patterns, heaps, dict of groups) is host-side;
embedding extension — the actual compute — is the vectorized CSR/bitset
path in :mod:`repro.core.patterns` (DESIGN.md §2: host orchestrates,
device-shaped arrays do the work).

Also implements the paper's comparison baseline
(:func:`arabesque_style_mining`): level-synchronous edge-oriented expansion
with an a-priori support threshold ``T`` — the Abq-µ / Abq-µ/3 runs of
Figures 12-14 — which cannot prioritize and must finish every level.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from .graph import GraphStore
from .labels import LABEL_FILTERS, LabelPredicate
from .patterns import (Code, PatternGroup, expand_group, seed_groups)


@dataclasses.dataclass
class MiningResult:
    patterns: List[Tuple[Code, int]]      # [(code, support)] best-first
    candidates: int                       # embeddings materialized (metric 1)
    groups_expanded: int
    groups_pruned: int
    completed: bool = True


class TopKPatternMiner:
    """Steppable form of Algorithm 2: :meth:`step` pops and processes one
    group from the priority heap.

    :func:`topk_frequent_patterns` is the run-to-completion loop; the
    service scheduler (DESIGN.md §9.2) interleaves `step` calls of many
    queries instead — both drive this single implementation, so the
    prioritize/prune semantics cannot diverge between them.
    """

    def __init__(self, g: GraphStore, m_edges: int, k: int = 1,
                 max_candidates: int = 50_000_000,
                 use_pallas: bool = False,
                 interpret: Optional[bool] = None,
                 predicate: Optional[LabelPredicate] = None,
                 label_filter: str = "pushdown"):
        assert label_filter in LABEL_FILTERS, label_filter
        self.g = g
        self.m_edges = m_edges
        self.k = k
        self.max_candidates = max_candidates
        # kernel-path knobs for embedding extension (byte-identical results;
        # DESIGN.md §10) — forwarded to every expand_group call
        self.use_pallas = use_pallas
        self.interpret = interpret
        # label-constrained mining (DESIGN.md §12): the predicate filters
        # seeds here and rides every expand_group call; label_filter picks
        # pushdown (filter before materialization) vs post (the host-side
        # baseline) — identical patterns/supports, different candidates
        self.predicate = predicate
        self.label_filter = label_filter
        groups = seed_groups(g, predicate=predicate)
        self.candidates = sum(len(gr.embeddings) for gr in groups.values())
        self._counter = itertools.count()
        self._pq: List[tuple] = []
        for code, gr in groups.items():
            sup = gr.support()
            # max-heap via negated lexicographic (m, f)
            heapq.heappush(self._pq,
                           ((-len(code), -sup), next(self._counter), gr, sup))
        self._results: List[Tuple[int, Code]] = []  # (support, code), sorted
        self.steps = 0
        self.expanded = 0
        self.pruned = 0
        self.completed = True     # False once the candidate budget is hit
        self.done = not self._pq

    def _kth_support(self) -> Optional[int]:
        return (self._results[self.k - 1][0]
                if len(self._results) >= self.k else None)

    def step(self) -> None:
        if self.done:
            return
        self.steps += 1
        _, _, gr, sup = heapq.heappop(self._pq)
        thr = self._kth_support()
        # relevant(S): pattern of exactly M edges → result candidate
        if gr.num_edges == self.m_edges:
            if thr is None or sup >= thr:
                self._results.append((sup, gr.code))
                self._results.sort(key=lambda t: (-t[0], t[1]))
                del self._results[self.k:]
        # dominated(S, kth): anti-monotone support bound
        elif thr is not None and sup < thr:
            self.pruned += 1
        else:
            children, created = expand_group(
                self.g, gr, use_pallas=self.use_pallas,
                interpret=self.interpret, predicate=self.predicate,
                label_filter=self.label_filter)
            self.candidates += created
            self.expanded += 1
            if self.candidates > self.max_candidates:
                self.completed = False
                self.done = True
                return
            thr = self._kth_support()
            for code, child in children.items():
                csup = child.support()
                if thr is not None and csup < thr:    # line 26 pruning
                    self.pruned += 1
                    continue
                heapq.heappush(self._pq, ((-len(code), -csup),
                                          next(self._counter), child, csup))
        if not self._pq:
            self.done = True

    def result(self) -> MiningResult:
        return MiningResult([(s, c) for s, c in self._results],
                            self.candidates, self.expanded, self.pruned,
                            completed=self.completed)


def topk_frequent_patterns(g: GraphStore, m_edges: int, k: int = 1,
                           max_candidates: int = 50_000_000,
                           use_pallas: bool = False,
                           interpret: Optional[bool] = None,
                           predicate: Optional[LabelPredicate] = None,
                           label_filter: str = "pushdown") -> MiningResult:
    """Nuri: prioritized + pruned top-k mining of M-edge patterns (Alg. 2)."""
    miner = TopKPatternMiner(g, m_edges, k, max_candidates,
                             use_pallas=use_pallas, interpret=interpret,
                             predicate=predicate, label_filter=label_filter)
    while not miner.done:
        miner.step()
    return miner.result()


def arabesque_style_mining(g: GraphStore, m_edges: int, threshold: int,
                           max_candidates: int = 50_000_000,
                           use_pallas: bool = False,
                           interpret: Optional[bool] = None) -> MiningResult:
    """Arabesque-style baseline: level-synchronous frequent-pattern mining
    with a user-supplied threshold ``T`` (paper §6.3).

    All patterns of size m are expanded before any of size m+1 (no
    prioritization); the only pruning is the a-priori ``support >= T``
    filter.  Top-k is selected a posteriori among the M-edge patterns.
    """
    groups = seed_groups(g)
    candidates = sum(len(gr.embeddings) for gr in groups.values())
    expanded = pruned = 0
    level = {c: gr for c, gr in groups.items()
             if gr.support() >= threshold}
    finals: List[Tuple[int, Code]] = []
    for _ in range(m_edges - 1):
        nxt: Dict[Code, PatternGroup] = {}
        for gr in level.values():
            children, created = expand_group(g, gr, use_pallas=use_pallas,
                                             interpret=interpret)
            candidates += created
            expanded += 1
            if candidates > max_candidates:
                return MiningResult(finals, candidates, expanded, pruned,
                                    completed=False)
            for code, child in children.items():
                if child.support() >= threshold:
                    if code not in nxt:
                        nxt[code] = child
                else:
                    pruned += 1
        level = nxt
    finals = sorted(((gr.support(), c) for c, gr in level.items()),
                    key=lambda t: (-t[0], t[1]))
    return MiningResult(finals, candidates, expanded, pruned)


def max_support_of_size(g: GraphStore, m_edges: int) -> int:
    """µ — the maximum support over M-edge patterns (used to position the
    baseline's threshold at µ and µ/3 as in Figures 12-14)."""
    res = topk_frequent_patterns(g, m_edges, k=1)
    return res.patterns[0][0] if res.patterns else 0
