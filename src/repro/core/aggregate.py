"""Aggregate computational model — paper Algorithm 2 (top-k pattern mining).

Groups subgraphs by their grouping key (the pattern's minimal DFS code),
keeps a priority queue of *groups*, and applies the paper's user functions
at group granularity:

* ``key(s)``        — the minimal DFS code (pattern-oriented expansion),
* ``relevant(S)``   — pattern has exactly ``M`` edges,
* ``priority(S)``   — lexicographic ``(m(S), f(S))`` (edge count, support):
  larger patterns first, then more frequent ones (paper §3.3),
* ``dominated(S,S')`` — ``f(S) < f(S')`` — sound because minimum
  image-based support is anti-monotone [5].

Ragged group bookkeeping (patterns, heaps, dict of groups) is host-side;
embedding extension — the actual compute — is the vectorized CSR/bitset
path in :mod:`repro.core.patterns` (DESIGN.md §2: host orchestrates,
device-shaped arrays do the work).

Also implements the paper's comparison baseline
(:func:`arabesque_style_mining`): level-synchronous edge-oriented expansion
with an a-priori support threshold ``T`` — the Abq-µ / Abq-µ/3 runs of
Figures 12-14 — which cannot prioritize and must finish every level.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from .graph import GraphStore
from .patterns import (Code, PatternGroup, expand_group, seed_groups)


@dataclasses.dataclass
class MiningResult:
    patterns: List[Tuple[Code, int]]      # [(code, support)] best-first
    candidates: int                       # embeddings materialized (metric 1)
    groups_expanded: int
    groups_pruned: int
    completed: bool = True


def topk_frequent_patterns(g: GraphStore, m_edges: int, k: int = 1,
                           max_candidates: int = 50_000_000) -> MiningResult:
    """Nuri: prioritized + pruned top-k mining of M-edge patterns (Alg. 2)."""
    groups = seed_groups(g)
    candidates = sum(len(gr.embeddings) for gr in groups.values())
    counter = itertools.count()
    pq: List[tuple] = []
    for code, gr in groups.items():
        sup = gr.support()
        # max-heap via negated lexicographic (m, f)
        heapq.heappush(pq, ((-len(code), -sup), next(counter), gr, sup))

    results: List[Tuple[int, Code]] = []   # (support, code), kept sorted
    expanded = pruned = 0

    def kth_support() -> Optional[int]:
        return results[k - 1][0] if len(results) >= k else None

    while pq:
        _, _, gr, sup = heapq.heappop(pq)
        thr = kth_support()
        # relevant(S): pattern of exactly M edges → result candidate
        if gr.num_edges == m_edges:
            if thr is None or sup >= thr:
                results.append((sup, gr.code))
                results.sort(key=lambda t: (-t[0], t[1]))
                del results[k:]
            continue                        # M-edge groups are not expanded
        # dominated(S, kth): anti-monotone support bound
        if thr is not None and sup < thr:
            pruned += 1
            continue
        children, created = expand_group(g, gr)
        candidates += created
        expanded += 1
        if candidates > max_candidates:
            return MiningResult([(s, c) for s, c in results], candidates,
                                expanded, pruned, completed=False)
        thr = kth_support()
        for code, child in children.items():
            csup = child.support()
            if thr is not None and csup < thr:    # line 26 pruning
                pruned += 1
                continue
            heapq.heappush(pq, ((-len(code), -csup), next(counter),
                                child, csup))

    return MiningResult([(s, c) for s, c in results], candidates,
                        expanded, pruned)


def arabesque_style_mining(g: GraphStore, m_edges: int, threshold: int,
                           max_candidates: int = 50_000_000) -> MiningResult:
    """Arabesque-style baseline: level-synchronous frequent-pattern mining
    with a user-supplied threshold ``T`` (paper §6.3).

    All patterns of size m are expanded before any of size m+1 (no
    prioritization); the only pruning is the a-priori ``support >= T``
    filter.  Top-k is selected a posteriori among the M-edge patterns.
    """
    groups = seed_groups(g)
    candidates = sum(len(gr.embeddings) for gr in groups.values())
    expanded = pruned = 0
    level = {c: gr for c, gr in groups.items()
             if gr.support() >= threshold}
    finals: List[Tuple[int, Code]] = []
    for _ in range(m_edges - 1):
        nxt: Dict[Code, PatternGroup] = {}
        for gr in level.values():
            children, created = expand_group(g, gr)
            candidates += created
            expanded += 1
            if candidates > max_candidates:
                return MiningResult(finals, candidates, expanded, pruned,
                                    completed=False)
            for code, child in children.items():
                if child.support() >= threshold:
                    if code not in nxt:
                        nxt[code] = child
                else:
                    pruned += 1
        level = nxt
    finals = sorted(((gr.support(), c) for c, gr in level.items()),
                    key=lambda t: (-t[0], t[1]))
    return MiningResult(finals, candidates, expanded, pruned)


def max_support_of_size(g: GraphStore, m_edges: int) -> int:
    """µ — the maximum support over M-edge patterns (used to position the
    baseline's threshold at µ and µ/3 as in Figures 12-14)."""
    res = topk_frequent_patterns(g, m_edges, k=1)
    return res.patterns[0][0] if res.patterns else 0
