"""Top-k subgraph isomorphism on the engine (paper §4.3, Ullmann [54] +
Gupta-style index [23]).

Finds the k highest-scored subgraphs of a labeled data graph isomorphic to a
query graph, score = Σ degree of matched data vertices.  Semantics follow the
paper's definition (§2.1): the bijection preserves labels and adjacency *iff*
(induced isomorphism).

State layout (``S = nq + 2`` int32): ``mapping[nq]`` (data vertex per query
vertex, -1 unmatched), ``depth`` (matched count), ``score``.

Targeted expansion: the candidate set for the next query vertex ``j`` is
computed as a bitset intersection over all already-matched query vertices
``i`` — ``adj(map[i])`` when ``(i,j) ∈ E_q`` and its complement otherwise —
AND the label-``l_j`` vertex bitset (or the OR-ed bitset of ``j``'s label
class under a :class:`~repro.core.labels.LabelPredicate`), minus used
vertices.  Only vertices in that set are ever materialized (Ullmann-style
forward checking).  Label predicates push down into the same product:
the allowed-vertex bitset seeds the constraint mask and ``edge_any_of``
swaps in the type-restricted adjacency (DESIGN.md §12).

Pruning/prioritization: the per-vertex index ``index[v, l, h]`` = max degree
over label-``l`` vertices exactly ``h`` hops from ``v`` (paper Fig. 7) gives
``u(s) = Σ_{unmatched t} index[seed, label_q(t), hop_q(t)]``; priority is the
paper's ``(edgeCount, score + u)`` and ``dominated`` compares ``score + u``
with the k-th result score.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import bitset
from .api import NEG, SubgraphComputation
from .graph import GraphStore
from .labels import LABEL_FILTERS, LabelPredicate


# ----------------------------------------------------------------- the index
def build_iso_index(graph: GraphStore, max_hops: int,
                    predicate: Optional[LabelPredicate] = None
                    ) -> np.ndarray:
    """``index[v, l, h]`` = max degree over label-l vertices exactly h hops
    from v (h in 1..max_hops; h index 0 is hop 1).  Shape [N, L, H].

    Built with dense boolean matmuls (device) — the paper notes index
    construction is embarrassingly parallel; here one matmul per hop does
    all vertices at once.

    When a predicate restricts edge types (``edge_any_of``), hop
    reachability must be computed on the *restricted* adjacency — full-
    graph hop distances do not bound restricted-graph ones, so the full
    index would be unsound for label-constrained queries (a valid match
    at restricted distance h can sit at full distance < h and miss its
    exact-hop index slot).  Degrees stay full-graph: the relevance score
    is the full-graph degree sum regardless of the predicate
    (DESIGN.md §12).  Pass the same predicate here and to
    :func:`make_iso_computation`; the service layer keys its index cache
    by (graph fingerprint, max_hops, allowed edge types) and does this
    automatically.
    """
    assert graph.labels is not None, "iso index requires a labeled graph"
    n = graph.n
    n_labels = int(graph.labels.max()) + 1
    adj = jnp.zeros((n, n), jnp.float32)
    ea = graph.edge_array
    if predicate is not None and predicate.edge_any_of is not None:
        ea = ea[predicate.edge_mask_csr(graph)]
    adj = adj.at[ea[:, 0], ea[:, 1]].set(1.0)
    deg = jnp.asarray(graph.degrees, jnp.float32)
    labels = np.asarray(graph.labels)

    index = np.zeros((n, n_labels, max_hops), np.int32)
    reached = jnp.eye(n, dtype=jnp.float32)           # vertices within h-1 hops
    frontier = jnp.eye(n, dtype=jnp.float32)
    for h in range(max_hops):
        nxt = (frontier @ adj > 0).astype(jnp.float32)
        level = jnp.clip(nxt - reached, 0.0, 1.0)     # exactly h+1 hops away
        reached = jnp.clip(reached + nxt, 0.0, 1.0)
        frontier = level
        level_np = np.asarray(level)
        for l in range(n_labels):
            degl = np.where(labels == l, np.asarray(deg), 0.0)
            index[:, l, h] = (level_np * degl[None, :]).max(axis=1)
    return index


def _query_order(q_edges: Sequence[Tuple[int, int]], nq: int) -> List[int]:
    """BFS order from query vertex 0 so every matched vertex has a matched
    neighbor (connected expansion)."""
    adj = [[] for _ in range(nq)]
    for a, b in q_edges:
        adj[a].append(b)
        adj[b].append(a)
    order, seen = [0], {0}
    i = 0
    while len(order) < nq:
        if i >= len(order):                      # disconnected query
            rest = [v for v in range(nq) if v not in seen]
            order.append(rest[0])
            seen.add(rest[0])
            continue
        for u in sorted(adj[order[i]]):
            if u not in seen:
                order.append(u)
                seen.add(u)
        i += 1
    return order


def _query_hops(q_edges, nq) -> np.ndarray:
    """Hop distance from query vertex 0 inside the query graph."""
    adj = [[] for _ in range(nq)]
    for a, b in q_edges:
        adj[a].append(b)
        adj[b].append(a)
    dist = np.full(nq, nq, np.int32)
    dist[0] = 0
    frontier = [0]
    d = 0
    while frontier:
        d += 1
        nxt = []
        for v in frontier:
            for u in adj[v]:
                if dist[u] > d:
                    dist[u] = d
                    nxt.append(u)
        frontier = nxt
    return dist


def make_iso_computation(graph: GraphStore,
                         q_edges: Sequence[Tuple[int, int]],
                         q_labels: Sequence[int],
                         index: np.ndarray,
                         induced: bool = True,
                         use_pallas: bool = False,
                         interpret: Optional[bool] = None,
                         cand_path: str = "batched",
                         predicate: Optional[LabelPredicate] = None,
                         label_filter: str = "pushdown"
                         ) -> SubgraphComputation:
    """Build the iso :class:`SubgraphComputation`.

    Candidate-generation path (byte-identical results, DESIGN.md §10):

    * ``use_pallas=True`` — batched constraint product, then the
      masked-intersection Pallas kernel materializes the [B, N] candidate
      grid for the whole dequeued batch in one call (``interpret=None``
      auto-detects the backend; ``cand_path`` is ignored);
    * ``cand_path="batched"`` (default) — same batched constraint
      product, jnp membership unpack (the kernel's reference path);
    * ``cand_path="vmap"`` — the legacy per-state ``fori_loop`` under
      ``vmap``;
    * ``cand_path="map"`` — the per-state loop run truly one state at a
      time (``lax.map``), the paper's Algorithm-1 form and the baseline
      ``benchmarks/bench_iso.py`` measures the batched paths against.

    Label-constrained discovery (DESIGN.md §12): ``predicate`` restricts
    which data vertices/edges may participate.  ``q_any_of`` replaces the
    exact per-query-vertex label with a label *class* (the row operand of
    the kernel becomes the class's OR-ed label bitset); ``edge_any_of``
    swaps the constraint product's adjacency for the type-restricted
    adjacency (both structural — they change matching semantics and apply
    in every mode).  ``vertex_any_of`` is a pure filter with two
    placements selected by ``label_filter``:

    * ``"pushdown"`` — the allowed-vertex bitset seeds the per-row
      constraint mask of the masked-intersection kernel (infeasible
      candidates die inside the kernel at no extra pass) *and* the
      priority index is restricted to allowed labels, so states with no
      label-feasible extension are dominance-pruned before expansion —
      the paper's proactive pruning;
    * ``"post"`` — the unconstrained candidate grid is materialized and
      the predicate is applied afterwards as a boolean AND (the
      host-side-filtering baseline; the upper-bound index never sees the
      predicate).

    Complete runs return byte-identical top-k in both modes
    (``benchmarks/bench_labeled.py`` asserts it while measuring the
    pushdown win); budget-truncated runs may differ, which is why
    ``label_filter`` joins the service result-cache key.
    """
    assert cand_path in ("batched", "vmap", "map"), cand_path
    assert label_filter in LABEL_FILTERS, label_filter
    assert graph.labels is not None
    if predicate is not None:
        predicate.validate(graph, "iso", nq=len(q_labels))
    n = graph.n
    nq = len(q_labels)
    S = nq + 2
    w = bitset.num_words(n)

    # reorder query vertices so expansion is always connected
    order = _query_order(q_edges, nq)
    inv = {v: i for i, v in enumerate(order)}
    q_labels_o = np.asarray([q_labels[v] for v in order], np.int32)
    q_adj_o = np.zeros((nq, nq), bool)
    for a, b in q_edges:
        q_adj_o[inv[a], inv[b]] = q_adj_o[inv[b], inv[a]] = True
    hops_o = _query_hops(q_edges, nq)[order]       # distance from seed vertex

    # per-query-vertex label classes (exact q_labels when no q_any_of),
    # in expansion order
    if predicate is not None and predicate.q_any_of is not None:
        classes_o = [tuple(predicate.q_any_of[v]) for v in order]
    else:
        classes_o = [(int(l),) for l in q_labels_o]
    # the global vertex predicate, as packed bitset + boolean vector
    allowed_vbits = predicate.vertex_bits(graph) if predicate else None
    allowed_vmask = predicate.vertex_mask(graph) if predicate else None
    pushdown = label_filter == "pushdown"

    max_hops = index.shape[2]
    hops_clamped = np.clip(hops_o, 1, max_hops)
    # ub_rest[v, d] = Σ_{t >= d} max_{l ∈ L_t} index[v, l, hop(t)] (seed = v)
    # where L_t is slot t's label class — under pushdown additionally
    # intersected with the allowed-label set, which tightens the bound
    # (still sound: it over-approximates the best completion that satisfies
    # the predicate).  The post baseline keeps the unrestricted classes.
    per_t = np.zeros((n, nq), np.int32)
    for t in range(nq):
        lt = classes_o[t]
        if pushdown and predicate is not None and \
                predicate.vertex_any_of is not None:
            lt = tuple(l for l in lt if l in predicate.vertex_any_of)
        if lt:
            per_t[:, t] = index[:, list(lt), hops_clamped[t] - 1].max(axis=1)
    suffix = np.cumsum(per_t[:, ::-1], axis=1)[:, ::-1]     # [N, nq]
    ub_rest = np.concatenate(
        [suffix, np.zeros((n, 1), np.int32)], axis=1)       # [N, nq+1]

    # constraint-product adjacency: restricted to allowed edge types when
    # the predicate carries edge_any_of (structural; both filter modes)
    adjc = predicate.adjacency(graph) if predicate is not None \
        else graph.adj_bits
    # class bitsets: the kernel's per-row label operand, one row per slot
    class_bits = np.stack([
        np.bitwise_or.reduce(graph.label_bits[list(cls)], axis=0)
        for cls in classes_o])                              # [nq, W]

    deg = jnp.asarray(graph.degrees, jnp.int32)
    adj_bits = jnp.asarray(adjc)
    class_bits_d = jnp.asarray(class_bits)
    ub_rest_d = jnp.asarray(ub_rest, jnp.int32)
    q_adj_d = jnp.asarray(q_adj_o)
    eye_bits = jnp.asarray(bitset.eye_table(n))
    allowed_vbits_d = (jnp.asarray(allowed_vbits)
                       if allowed_vbits is not None else None)
    allowed_vmask_d = (jnp.asarray(allowed_vmask)
                       if allowed_vmask is not None else None)
    if use_pallas:
        from repro.kernels import ops as kops

    max_deg = int(graph.degrees.max())
    base = int(2 * nq * max_deg + max_deg + 2)     # lexicographic stride
    assert (nq + 1) * base < 2 ** 31

    full_word = jnp.uint32(0xFFFFFFFF)

    def _cand_parts(states):
        """Batched candidate generation for a whole dequeued batch: per-row
        label bitsets and constraint masks (adjacency/complement products
        ∧ ~used), one gather + AND-reduce instead of a per-state loop.

        The candidate set of state ``b`` is ``lbl[b] & mask[b]``; the two
        parts are returned separately because they are exactly the
        (rows, row-mask) operands of the masked-intersection kernel.

        The constraint-slot loop is statically unrolled over ``nq`` with
        [B, W]-shaped operations only — no sequential ``fori_loop`` carry
        and no [B, nq, W] temporaries, which is what makes this path
        faster than the per-state loop (benchmarks/bench_iso.py).
        """
        b = states.shape[0]
        mapping = states[:, :nq]                        # [B, nq]
        d = states[:, nq]                               # [B]
        j = jnp.minimum(d, nq - 1)
        lbl = class_bits_d[j]                           # [B, W]
        if pushdown and allowed_vbits_d is not None:
            # predicate pushdown: the allowed-vertex bitset seeds the
            # per-row kernel mask, so label-infeasible candidates are
            # culled inside the masked intersection (DESIGN.md §12)
            mask = jnp.broadcast_to(allowed_vbits_d, (b, w))
        else:
            mask = jnp.full((b, w), full_word)
        used = jnp.zeros((b, w), jnp.uint32)
        for i in range(nq):                             # static: nq small
            mi = jnp.maximum(mapping[:, i], 0)          # [B]
            row = adj_bits[mi]                          # [B, W]
            need = q_adj_d[i][j]                        # [B] (q_adj symmetric)
            con = jnp.where(need[:, None], row, ~row) if induced else \
                jnp.where(need[:, None], row, full_word)
            active = (i < d)[:, None]                   # [B, 1]
            mask = jnp.where(active, mask & con, mask)
            used = jnp.where(active, used | eye_bits[mi], used)
        mask = mask & ~used
        return lbl, jnp.where((d < nq)[:, None], mask, jnp.uint32(0))

    def _cand_bits(state):
        """Per-state loop form of :func:`_cand_parts` (legacy reference,
        kept for the `cand_path="vmap"/"map"` benchmark baselines)."""
        mapping = state[:nq]
        d = state[nq]
        j = jnp.minimum(d, nq - 1)
        acc = class_bits_d[j]
        if pushdown and allowed_vbits_d is not None:
            acc = acc & allowed_vbits_d

        def body(i, carry):
            acc, used = carry
            mi = jnp.maximum(mapping[i], 0)
            row = adj_bits[mi]
            need = q_adj_d[i, j]
            constraint = jnp.where(need, row, ~row) if induced else \
                jnp.where(need, row, jnp.uint32(0xFFFFFFFF))
            active = i < d
            acc = jnp.where(active, acc & constraint, acc)
            used = jnp.where(active, bitset.set_bit(used, mi), used)
            return acc, used

        acc, used = jax.lax.fori_loop(
            0, nq, body, (acc, jnp.zeros((w,), jnp.uint32)))
        acc = acc & ~used
        return jnp.where(d < nq, acc, jnp.zeros((w,), jnp.uint32))

    def init_frontier():
        # seed = vertices matching slot 0's label class; the vertex
        # predicate applies here in BOTH filter modes — the frontier is
        # seeded host-side, and an unfiltered disallowed seed could
        # complete into a violating result (the post mode only defers
        # filtering of *candidate* vertices)
        seed_ok = np.isin(np.asarray(graph.labels), list(classes_o[0]))
        if allowed_vmask is not None:
            seed_ok &= allowed_vmask
        seeds = np.nonzero(seed_ok)[0]
        n0 = len(seeds)
        states = np.full((n0, S), -1, np.int32)
        states[:, 0] = seeds
        states[:, nq] = 1                                    # depth
        sc = graph.degrees[seeds].astype(np.int32)
        states[:, nq + 1] = sc
        ub = sc + ub_rest[seeds, 1]
        prio = 1 * base + ub
        return (jnp.asarray(states), jnp.asarray(prio, jnp.int32),
                jnp.asarray(ub, jnp.int32))

    def score_children(states):
        if use_pallas:
            lbl, mask = _cand_parts(states)
            in_cand = kops.masked_intersect(
                lbl, eye_bits, mask, interpret=interpret) > 0    # [B, N]
        elif cand_path == "batched":
            lbl, mask = _cand_parts(states)
            in_cand = bitset.to_bool(lbl & mask, n)              # [B, N]
        elif cand_path == "vmap":
            cand = jax.vmap(_cand_bits)(states)                  # [B, W]
            in_cand = bitset.to_bool(cand, n)                    # [B, N]
        else:  # "map": one state at a time (the pre-batching loop form)
            cand = jax.lax.map(_cand_bits, states)               # [B, W]
            in_cand = bitset.to_bool(cand, n)                    # [B, N]
        if not pushdown and allowed_vmask_d is not None:
            # host-side-filter baseline: the unconstrained candidate grid
            # was materialized above; the predicate lands only now
            in_cand = in_cand & allowed_vmask_d[None, :]
        d = states[:, nq]
        score = states[:, nq + 1]
        seed = jnp.maximum(states[:, 0], 0)
        nd = jnp.minimum(d + 1, nq)
        rest = ub_rest_d[seed, nd]                           # [B]
        child_score = score[:, None] + deg[None, :]
        child_ub = child_score + rest[:, None]
        child_prio = nd[:, None] * base + child_ub
        invalid = ~in_cand
        return (jnp.where(invalid, NEG, child_prio),
                jnp.where(invalid, NEG, child_ub))

    def materialize(states, actions):
        d = states[:, nq]
        b = states.shape[0]
        row = jnp.arange(b)
        out = states.at[row, d].set(actions)
        out = out.at[row, nq].add(1)
        out = out.at[row, nq + 1].add(deg[actions])
        return out

    def result_key(states):
        complete = states[:, nq] == nq
        return jnp.where(complete, states[:, nq + 1], NEG)

    def upper_bound(states):
        d = states[:, nq]
        seed = jnp.maximum(states[:, 0], 0)
        return states[:, nq + 1] + ub_rest_d[seed, jnp.minimum(d, nq)]

    def describe(state_row: np.ndarray) -> list:
        m = list(map(int, state_row[:nq]))
        return [m[inv[v]] for v in range(nq)]    # original query order

    return SubgraphComputation(
        name="iso", state_width=S, num_actions=n,
        init_frontier=init_frontier, score_children=score_children,
        materialize=materialize, result_key=result_key,
        upper_bound=upper_bound, describe=describe)
