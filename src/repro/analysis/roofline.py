"""Roofline analysis over the dry-run artifacts.

Per (arch × shape × mesh) cell, derives the three roofline terms from the
compiled single-pod dry-run (hardware: TPU v5e):

* compute    = HLO_FLOPs / (chips × 197e12 FLOP/s bf16)
* memory     = HLO_bytes / (chips × 819e9 B/s HBM)
* collective = collective_operand_bytes / (chips × 50e9 B/s per ICI link)

``cost_analysis`` is *per-device* on the partitioned module, so FLOPs/bytes
are already divided by the chip count — terms below use the per-device
numbers directly against one chip's peaks.  Collective bytes come from the
HLO text parse (operand bytes per collective op, scan-corrected by the
probe fit; see launch/dryrun.py).

MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for training; 2·N·D for
inference forward passes.  The ratio MODEL_FLOPS / HLO_FLOPS_global shows
how much compiled compute is "useful" (remat/dispatch/attention overheads
push it below 1).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link


def load_cells(art_dir: str = "artifacts/dryrun",
               mesh: str = "single") -> List[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(art_dir, mesh, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def model_flops(rec: dict) -> float:
    meta = rec.get("meta", {})
    n_active = meta.get("active_params", 0)
    kind = meta.get("kind", "train")
    if "tokens" in meta:
        d = meta["tokens"]
    elif "batch" in meta:
        d = meta["batch"]
    elif "candidates" in meta:
        d = meta["candidates"]
    else:
        d = meta.get("nodes", 0)
    factor = 6 if kind == "train" else 2
    return factor * n_active * d


def roofline_terms(rec: dict) -> Optional[dict]:
    if not rec.get("ok"):
        return None
    chips = 1
    for v in rec["mesh_shape"].values():
        chips *= v
    cost = rec["cost"]
    colls = rec.get("collectives", {})
    coll_bytes = sum(v.get("operand_bytes", 0) for v in colls.values())
    t_compute = cost["flops"] / PEAK_FLOPS
    t_memory = cost["bytes_accessed"] / HBM_BW
    t_coll = coll_bytes / LINK_BW
    terms = dict(compute_s=t_compute, memory_s=t_memory,
                 collective_s=t_coll)
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = cost["flops"] * chips
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=chips, **terms,
        dominant=dominant.replace("_s", ""),
        bound_s=max(terms.values()),
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=(mf / hlo_global) if hlo_global else 0.0,
        peak_gib=rec["memory"]["peak_bytes"] / 2 ** 30,
        roofline_fraction=(min(t_compute, max(terms.values())) and
                           t_compute / max(terms.values())),
        collectives=colls,
    )


def table(mesh: str = "single", art_dir: str = "artifacts/dryrun"
          ) -> List[dict]:
    rows = []
    for rec in load_cells(art_dir, mesh):
        t = roofline_terms(rec)
        if t:
            rows.append(t)
    return rows


def format_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bound | "
           "roofline frac | MODEL/HLO | peak GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_ratio']:.2f} | {r['peak_gib']:.1f} |")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    rows = table(args.mesh, args.dir)
    print(format_markdown(rows))
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        collb = max(rows, key=lambda r: r["collective_s"])
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline_fraction']:.2f})")
        print(f"most collective-bound:   {collb['arch']}/{collb['shape']} "
              f"({collb['collective_s']:.3e}s)")


if __name__ == "__main__":
    main()
