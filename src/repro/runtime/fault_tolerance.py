"""Fault-tolerance runtime: straggler watch, heartbeats, elastic remesh.

On a real multi-pod deployment this process runs per host; here the same
logic is exercised single-host (tests simulate failures and slow steps).

* :class:`StragglerMonitor` — EMA step-time watchdog.  A step slower than
  ``threshold × EMA`` is flagged; the training driver responds by (a)
  logging the event, (b) optionally shrinking the per-host microbatch
  ("bounded-staleness dispatch": slow hosts contribute fewer microbatches
  to the next accumulation window instead of stalling the collective).
* :class:`Heartbeat` — liveness file the launcher touches every step; an
  external supervisor (or another host) declares the worker dead when the
  heartbeat goes stale and restarts it — restart then resumes from the
  latest committed checkpoint (see ``launch/train.py --fail-at-step``).
* :func:`elastic_remesh` — reload a checkpoint onto a different mesh shape
  (scale up/down): checkpoints store full arrays, so re-sharding is a
  device_put with the new shardings; the step counter carries over.
"""
from __future__ import annotations

import os
import time
from typing import Optional

import jax


class StragglerMonitor:
    def __init__(self, threshold: float = 2.5, ema: float = 0.9,
                 warmup_steps: int = 3):
        self.threshold = threshold
        self.ema_factor = ema
        self.warmup = warmup_steps
        self.ema: Optional[float] = None
        self.seen = 0
        self.events: list = []

    def record(self, step: int, duration: float) -> bool:
        """Returns True when this step is a straggler."""
        self.seen += 1
        if self.seen <= self.warmup:
            self.ema = duration if self.ema is None else \
                0.5 * (self.ema + duration)
            return False
        is_straggler = duration > self.threshold * self.ema
        if is_straggler:
            self.events.append((step, duration, self.ema))
        else:
            self.ema = self.ema_factor * self.ema + \
                (1 - self.ema_factor) * duration
        return is_straggler


class Heartbeat:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int):
        with open(self.path, "w") as f:
            f.write(f"{step} {time.time()}")

    @staticmethod
    def is_stale(path: str, timeout: float) -> bool:
        try:
            with open(path) as f:
                _, ts = f.read().split()
            return time.time() - float(ts) > timeout
        except (OSError, ValueError):
            return True


def elastic_remesh(manager, like, new_shardings, step: Optional[int] = None):
    """Restore the latest checkpoint re-sharded for a new mesh (elastic
    scale-up/down after node gain/loss)."""
    return manager.restore(like, step=step, shardings=new_shardings)
