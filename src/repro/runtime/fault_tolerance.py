"""Fault-tolerance runtime for durable discovery serving (DESIGN.md §15).

* :class:`StragglerMonitor` — EMA step-time watchdog.  The service layer
  runs one per live query (``repro.service.scheduler.EngineQueryTask``):
  an engine (macro-)step slower than ``threshold × EMA`` is flagged and
  the count is surfaced as ``stats["straggler_steps"]`` in the query's
  response — a per-query slow-step audit for multi-tenant serving.
* :class:`Heartbeat` — liveness file the serve loop
  (``repro.launch.serve --heartbeat``) touches after every flushed batch;
  an external supervisor declares the worker dead when the heartbeat goes
  stale, kills it, and restarts with ``--resume`` — checkpointed queries
  then continue from their newest committed step with answers
  byte-identical to an uninterrupted run (tests/test_fault_injection.py
  proves exactly this cycle under SIGKILL).
"""
from __future__ import annotations

import os
import time
from collections import deque
from typing import Optional


class StragglerMonitor:
    """``events`` keeps only the newest ``max_events`` straggler records
    (a long-lived serving query would otherwise grow it without bound);
    ``straggler_steps`` is the monotone total and is what response stats
    report."""

    def __init__(self, threshold: float = 2.5, ema: float = 0.9,
                 warmup_steps: int = 3, max_events: int = 256):
        self.threshold = threshold
        self.ema_factor = ema
        self.warmup = warmup_steps
        self.ema: Optional[float] = None
        self.seen = 0
        self.straggler_steps = 0
        self.events: deque = deque(maxlen=max_events)

    def record(self, step: int, duration: float) -> bool:
        """Returns True when this step is a straggler."""
        self.seen += 1
        if self.seen <= self.warmup:
            self.ema = duration if self.ema is None else \
                0.5 * (self.ema + duration)
            return False
        is_straggler = duration > self.threshold * self.ema
        if is_straggler:
            self.straggler_steps += 1
            self.events.append((step, duration, self.ema))
        else:
            self.ema = self.ema_factor * self.ema + \
                (1 - self.ema_factor) * duration
        return is_straggler


class Heartbeat:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int):
        with open(self.path, "w") as f:
            f.write(f"{step} {time.time()}")

    @staticmethod
    def is_stale(path: str, timeout: float) -> bool:
        try:
            with open(path) as f:
                _, ts = f.read().split()
            return time.time() - float(ts) > timeout
        except (OSError, ValueError):
            return True
