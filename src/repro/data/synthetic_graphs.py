"""Synthetic data graphs for benchmarks and tests.

The paper evaluates on Email/CiteSeer/MiCo/YouTube/Patents; those files are
not available offline, so benchmarks use synthetic stand-ins with the same
relevant structure:

* :func:`densifying_graph` — the paper's densification protocol (§6.2):
  "created increasingly denser data graphs ... by repeatedly adding batches
  of randomly chosen edges to an empty graph".
* :func:`planted_clique_graph` — ER background + planted clique (lets tests
  assert the known maximum clique).
* :func:`powerlaw_graph` — preferential-attachment for skew-degree behavior.
* :func:`skewed_graph` — large Zipf-endpoint graphs with a skew knob (and
  optional planted clique) sized for the distributed benchmarks, where
  degree skew makes per-shard workloads unequal (DESIGN.md §14).
* :func:`decoy_trap_graph` — skewed background plus dense *decoy* clusters
  and a planted clique on one round-robin residue class: the workload
  where diversified sharded search + bound exchange beats single-device
  priority order outright (DESIGN.md §14).
* :func:`labeled_graph` — ER with vertex labels (CiteSeer-like) for pattern
  mining / isomorphism.
* :func:`attributed_graph` — ER with *skewed* vertex labels plus edge
  types (RDF/protein-interaction-like), for the label-constrained
  workloads: the geometric label frequencies give every selectivity
  regime a label set to sweep (``benchmarks/bench_labeled.py``).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import GraphStore


def densifying_graph(n: int, m: int, seed: int = 0) -> GraphStore:
    """n vertices, m random distinct undirected edges (paper §6.2 protocol)."""
    rng = np.random.default_rng(seed)
    seen = set()
    edges = []
    while len(edges) < m:
        need = m - len(edges)
        cand = rng.integers(0, n, size=(need * 2 + 16, 2))
        for u, v in cand:
            if u == v:
                continue
            key = (min(u, v), max(u, v))
            if key in seen:
                continue
            seen.add(key)
            edges.append(key)
            if len(edges) == m:
                break
    return GraphStore.from_edges(n, np.array(edges))


def planted_clique_graph(n: int, m: int, clique_size: int,
                         seed: int = 0) -> GraphStore:
    """ER(n, m) plus a planted clique on ``clique_size`` random vertices."""
    rng = np.random.default_rng(seed)
    g = densifying_graph(n, m, seed)
    members = rng.choice(n, size=clique_size, replace=False)
    extra = [(u, v) for i, u in enumerate(members) for v in members[i + 1:]]
    edges = np.concatenate([g.edge_array, np.array(extra, np.int32)])
    return GraphStore.from_edges(n, edges)


def powerlaw_graph(n: int, m_per_node: int, seed: int = 0) -> GraphStore:
    """Barabási–Albert preferential attachment."""
    rng = np.random.default_rng(seed)
    edges = []
    targets = list(range(m_per_node))
    repeated = []
    for v in range(m_per_node, n):
        for t in targets:
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * m_per_node)
        targets = [repeated[i] for i in
                   rng.integers(0, len(repeated), size=m_per_node)]
    return GraphStore.from_edges(n, np.array(edges))


def labeled_graph(n: int, m: int, n_labels: int, seed: int = 0) -> GraphStore:
    """ER(n, m) with uniform random vertex labels."""
    rng = np.random.default_rng(seed + 1)
    g = densifying_graph(n, m, seed)
    labels = rng.integers(0, n_labels, size=n).astype(np.int32)
    return GraphStore.from_edges(n, g.edge_array, labels=labels)


def _skewed_edges(n: int, m: int, skew: float,
                  rng: np.random.Generator) -> np.ndarray:
    """``m`` distinct undirected edges with Zipf-weighted endpoints
    (vectorized rejection sampling; shared by the large-graph builders)."""
    p = (np.arange(n) + 1.0) ** -float(skew)
    p /= p.sum()
    keys: set = set()
    edges = np.empty((0, 2), np.int64)
    while len(edges) < m:
        need = m - len(edges)
        cand = rng.choice(n, size=(2 * need + 64, 2), p=p)
        cand = cand[cand[:, 0] != cand[:, 1]]
        cand.sort(axis=1)
        fresh = [
            (u, v) for u, v in cand
            if (u, v) not in keys and not keys.add((u, v))][:need]
        if fresh:
            edges = np.concatenate([edges, np.asarray(fresh, np.int64)])
    return edges


def skewed_graph(n: int, m: int, skew: float = 0.0, clique_size: int = 0,
                 seed: int = 0) -> GraphStore:
    """Degree-skewed random graph, sized for the distributed benchmarks.

    Endpoints of the ``m`` distinct undirected edges are drawn with
    probability proportional to ``(v + 1) ** -skew`` — ``skew = 0`` is the
    uniform densifying protocol, larger values concentrate edges on
    low-index vertices (Zipf-like hubs).  Skew is the knob that makes
    shard workloads *unequal* under round-robin seed partitioning: the
    dense hub neighborhoods all hash to a few shards' subtrees, so the
    rebalancer and the stale-bound exchange are both exercised under
    realistic imbalance (DESIGN.md §14).  ``clique_size > 0`` additionally
    plants a clique on random vertices so top-k clique instances have a
    known dominant answer.

    Vectorized rejection sampling (not the edge-at-a-time loop of
    :func:`densifying_graph`): benchmark graphs are 10-100x larger than
    the test graphs, and Python-loop generation would dominate bench
    setup time.
    """
    assert 0 < m <= n * (n - 1) // 2
    rng = np.random.default_rng(seed)
    edges = _skewed_edges(n, m, skew, rng)
    if clique_size > 0:
        members = rng.choice(n, size=clique_size, replace=False)
        extra = [(u, v) for i, u in enumerate(members)
                 for v in members[i + 1:]]
        edges = np.concatenate([edges, np.asarray(extra, np.int64)])
    return GraphStore.from_edges(n, edges)


def decoy_trap_graph(n: int, m: int, skew: float = 0.0, clusters: int = 7,
                     cluster_size: int = 100, cluster_p: float = 0.19,
                     clique_size: int = 7, stride: int = 8,
                     seed: int = 0) -> GraphStore:
    """Skewed background + dense decoy clusters + a clique planted on one
    round-robin residue class (DESIGN.md §14).

    The engine's priority is lexicographic ``(|V|, |P|)``: any size-2 state
    outranks every seed, so a single device must exhaust the decoy
    clusters' size-2 tier — thousands of states whose upper bound sits just
    *below* the planted answer's k-th key — before its dominance threshold
    can rise enough to prune them.  Under round-robin seed partitioning
    into ``stride`` shards, residue class ``stride - 1`` holds the planted
    clique and **no** decoy vertices: that shard reaches the answer within
    a few super-steps, and the bound exchange broadcasts a threshold that
    lets every other shard drop its decoy frontier at dequeue / VPQ refill
    instead of expanding it.  Total work is order-dependent (branch-and-
    bound diversification), which is what lets the sharded engine beat the
    single device on wall clock even when all forced host devices share
    one CPU core.

    Tuning contract (defaults satisfy it): with ``c = cluster_size`` and
    ``p = cluster_p``, a decoy size-2 state has upper bound
    ``~2 + c*p**2``; it must stay >= the best decoy clique size (so the
    single device cannot prune it from its own discoveries, ``c*p**3``
    small keeps decoy cliques at ~4-5) but < ``clique_size - 1`` (the
    planted run's threshold, so the exchanged bound kills it).
    """
    assert clique_size >= 3 and stride >= 2
    rng = np.random.default_rng(seed)
    edges = _skewed_edges(n, m, skew, rng)
    # decoy clusters: disjoint vertex sets drawn off the planted residue
    decoy_pool = np.array([v for v in range(n) if v % stride != stride - 1])
    picks = rng.choice(len(decoy_pool), size=(clusters, cluster_size),
                       replace=False)
    extra = [edges]
    for row in picks:
        mem = np.sort(decoy_pool[row])
        iu, iv = np.triu_indices(cluster_size, k=1)
        keep = rng.random(len(iu)) < cluster_p
        extra.append(np.stack([mem[iu[keep]], mem[iv[keep]]], axis=1))
    # planted clique on the decoy-free residue class, high-index half only:
    # low indices carry the Zipf mass, and a member that doubles as a skew
    # hub would be dequeued with the hubs and hand the single device the
    # answer (and the pruning threshold) without grinding the decoy tier
    lo = n // (2 * stride)
    members = (stride - 1) + stride * (lo + rng.choice(
        n // stride - lo, size=clique_size, replace=False))
    extra.append(np.array([(u, v) for i, u in enumerate(members)
                           for v in members[i + 1:]], np.int64))
    all_e = np.concatenate(extra)
    all_e.sort(axis=1)
    return GraphStore.from_edges(n, np.unique(all_e, axis=0))


def attributed_graph(n: int, m: int, n_labels: int, n_edge_labels: int = 0,
                     skew: float = 0.6, seed: int = 0) -> GraphStore:
    """ER(n, m) with skewed vertex labels and (optionally) edge types.

    Vertex labels follow a geometric distribution: label ``l`` has
    relative frequency ``skew**l`` (normalized), so low-index labels are
    common and high-index labels rare — a label predicate allowing only
    the tail labels is *low-selectivity* (few allowed vertices), which is
    the regime where predicate pushdown pays (DESIGN.md §12).  Every
    label is guaranteed at least one vertex.  Edge types are uniform over
    ``n_edge_labels`` (0 = untyped graph).
    """
    assert n_labels >= 1 and n >= n_labels
    rng = np.random.default_rng(seed + 1)
    g = densifying_graph(n, m, seed)
    freq = skew ** np.arange(n_labels)
    labels = rng.choice(n_labels, size=n, p=freq / freq.sum())
    # guarantee every label occurs so predicates over any label are
    # non-degenerate (deterministic: first n_labels vertices)
    labels[:n_labels] = np.arange(n_labels)
    ea = g.edge_array
    edge_labels = (rng.integers(0, n_edge_labels, size=len(ea))
                   if n_edge_labels > 0 else None)
    return GraphStore.from_edges(n, ea, labels=labels.astype(np.int32),
                                 edge_labels=edge_labels)
