"""Synthetic data graphs for benchmarks and tests.

The paper evaluates on Email/CiteSeer/MiCo/YouTube/Patents; those files are
not available offline, so benchmarks use synthetic stand-ins with the same
relevant structure:

* :func:`densifying_graph` — the paper's densification protocol (§6.2):
  "created increasingly denser data graphs ... by repeatedly adding batches
  of randomly chosen edges to an empty graph".
* :func:`planted_clique_graph` — ER background + planted clique (lets tests
  assert the known maximum clique).
* :func:`powerlaw_graph` — preferential-attachment for skew-degree behavior.
* :func:`labeled_graph` — ER with vertex labels (CiteSeer-like) for pattern
  mining / isomorphism.
* :func:`attributed_graph` — ER with *skewed* vertex labels plus edge
  types (RDF/protein-interaction-like), for the label-constrained
  workloads: the geometric label frequencies give every selectivity
  regime a label set to sweep (``benchmarks/bench_labeled.py``).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import GraphStore


def densifying_graph(n: int, m: int, seed: int = 0) -> GraphStore:
    """n vertices, m random distinct undirected edges (paper §6.2 protocol)."""
    rng = np.random.default_rng(seed)
    seen = set()
    edges = []
    while len(edges) < m:
        need = m - len(edges)
        cand = rng.integers(0, n, size=(need * 2 + 16, 2))
        for u, v in cand:
            if u == v:
                continue
            key = (min(u, v), max(u, v))
            if key in seen:
                continue
            seen.add(key)
            edges.append(key)
            if len(edges) == m:
                break
    return GraphStore.from_edges(n, np.array(edges))


def planted_clique_graph(n: int, m: int, clique_size: int,
                         seed: int = 0) -> GraphStore:
    """ER(n, m) plus a planted clique on ``clique_size`` random vertices."""
    rng = np.random.default_rng(seed)
    g = densifying_graph(n, m, seed)
    members = rng.choice(n, size=clique_size, replace=False)
    extra = [(u, v) for i, u in enumerate(members) for v in members[i + 1:]]
    edges = np.concatenate([g.edge_array, np.array(extra, np.int32)])
    return GraphStore.from_edges(n, edges)


def powerlaw_graph(n: int, m_per_node: int, seed: int = 0) -> GraphStore:
    """Barabási–Albert preferential attachment."""
    rng = np.random.default_rng(seed)
    edges = []
    targets = list(range(m_per_node))
    repeated = []
    for v in range(m_per_node, n):
        for t in targets:
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * m_per_node)
        targets = [repeated[i] for i in
                   rng.integers(0, len(repeated), size=m_per_node)]
    return GraphStore.from_edges(n, np.array(edges))


def labeled_graph(n: int, m: int, n_labels: int, seed: int = 0) -> GraphStore:
    """ER(n, m) with uniform random vertex labels."""
    rng = np.random.default_rng(seed + 1)
    g = densifying_graph(n, m, seed)
    labels = rng.integers(0, n_labels, size=n).astype(np.int32)
    return GraphStore.from_edges(n, g.edge_array, labels=labels)


def attributed_graph(n: int, m: int, n_labels: int, n_edge_labels: int = 0,
                     skew: float = 0.6, seed: int = 0) -> GraphStore:
    """ER(n, m) with skewed vertex labels and (optionally) edge types.

    Vertex labels follow a geometric distribution: label ``l`` has
    relative frequency ``skew**l`` (normalized), so low-index labels are
    common and high-index labels rare — a label predicate allowing only
    the tail labels is *low-selectivity* (few allowed vertices), which is
    the regime where predicate pushdown pays (DESIGN.md §12).  Every
    label is guaranteed at least one vertex.  Edge types are uniform over
    ``n_edge_labels`` (0 = untyped graph).
    """
    assert n_labels >= 1 and n >= n_labels
    rng = np.random.default_rng(seed + 1)
    g = densifying_graph(n, m, seed)
    freq = skew ** np.arange(n_labels)
    labels = rng.choice(n_labels, size=n, p=freq / freq.sum())
    # guarantee every label occurs so predicates over any label are
    # non-degenerate (deterministic: first n_labels vertices)
    labels[:n_labels] = np.arange(n_labels)
    ea = g.edge_array
    edge_labels = (rng.integers(0, n_edge_labels, size=len(ea))
                   if n_edge_labels > 0 else None)
    return GraphStore.from_edges(n, ea, labels=labels.astype(np.int32),
                                 edge_labels=edge_labels)
