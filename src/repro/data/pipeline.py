"""Deterministic, shard-aware synthetic data pipelines.

Every batch is a pure function of ``(seed, step, shard)`` — restart-safe
(resume at step N reproduces the exact stream, so checkpoint/restart is
bitwise-consistent) and host-local (each data shard draws only its slice,
no cross-host shuffle service needed at 1000+ nodes).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence

import numpy as np

from repro.core.graph import GraphStore


def _rng(seed: int, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


class TokenStream:
    """Synthetic LM batches with learnable structure (Zipf-ish unigram +
    short-range copy pattern, so a real model visibly reduces loss)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 shard: int = 0, num_shards: int = 1):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed, self.shard, self.num_shards = seed, shard, num_shards
        assert batch % num_shards == 0
        self.local_batch = batch // num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        r = _rng(self.seed, step, self.shard)
        zipf = np.clip(r.zipf(1.3, size=(self.local_batch, self.seq)),
                       1, self.vocab) - 1
        # copy pattern: second half repeats first half with small noise
        half = self.seq // 2
        tokens = zipf
        tokens[:, half:half * 2] = tokens[:, :half]
        targets = np.roll(tokens, -1, axis=1)
        targets[:, -1] = 0
        return {"tokens": tokens.astype(np.int32),
                "targets": targets.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class RecsysStream:
    def __init__(self, n_sparse: int, n_dense: int, vocab: int, batch: int,
                 seed: int = 0, shard: int = 0, num_shards: int = 1):
        self.n_sparse, self.n_dense = n_sparse, n_dense
        self.vocab, self.batch = vocab, batch
        self.seed, self.shard = seed, shard
        self.local_batch = batch // num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        r = _rng(self.seed, step, self.shard)
        ids = r.integers(0, self.vocab,
                         size=(self.local_batch, self.n_sparse))
        dense = r.normal(size=(self.local_batch, self.n_dense))
        # clickiness depends on a hidden linear model → learnable
        w = _rng(self.seed, 0, 10 ** 6).normal(size=self.n_dense)
        p = 1 / (1 + np.exp(-(dense @ w) * 0.5))
        labels = r.random(self.local_batch) < p
        return {"sparse_ids": ids.astype(np.int32),
                "dense": dense.astype(np.float32),
                "labels": labels.astype(np.float32)}


@dataclasses.dataclass
class SampledSubgraph:
    """Fixed-size padded output of the neighbor sampler."""
    features: np.ndarray      # [N_pad, F]
    positions: np.ndarray     # [N_pad, 3]
    edge_src: np.ndarray      # [E_pad]
    edge_dst: np.ndarray      # [E_pad]
    targets: np.ndarray       # [N_pad, O]
    node_mask: np.ndarray     # [N_pad]


class NeighborSampler:
    """GraphSAGE-style fanout sampler (e.g. 15-10) with fixed padded shapes.

    Seeds are drawn per (step, shard); each hop uniformly samples up to
    ``fanout[h]`` neighbors per frontier node (with replacement when the
    degree is smaller).  Output arrays are padded to the static maximum so
    the jitted train step never recompiles; padding edges point at a dummy
    node whose mask zeroes its loss contribution.
    """

    def __init__(self, graph: GraphStore, batch_nodes: int,
                 fanout: Sequence[int], d_feat: int, d_out: int = 1,
                 seed: int = 0, shard: int = 0, num_shards: int = 1):
        self.g = graph
        self.batch_nodes = batch_nodes // num_shards
        self.fanout = list(fanout)
        self.d_feat, self.d_out = d_feat, d_out
        self.seed, self.shard = seed, shard
        n_pad = frontier = self.batch_nodes
        e_pad = 0
        for f in self.fanout:
            e_h = frontier * f          # one edge per sampled neighbor
            e_pad += e_h
            n_pad += e_h
            frontier = e_h
        self.n_pad, self.e_pad = n_pad + 1, e_pad       # +1 dummy node

    def sample(self, step: int) -> SampledSubgraph:
        g, r = self.g, _rng(self.seed, step, self.shard)
        dummy = self.n_pad - 1
        seeds = r.integers(0, g.n, size=self.batch_nodes)
        node_ids = [seeds]
        edges_src, edges_dst = [], []
        frontier_ids = seeds
        frontier_slots = np.arange(self.batch_nodes, dtype=np.int64)
        total = self.batch_nodes
        for f in self.fanout:
            deg = g.degrees[frontier_ids].astype(np.int64)
            pick = (r.random((len(frontier_ids), f)) *
                    np.maximum(deg, 1)[:, None]).astype(np.int64)
            base = g.indptr[frontier_ids].astype(np.int64)[:, None]
            nbrs = g.indices[np.minimum(base + pick, len(g.indices) - 1)]
            valid = np.repeat(deg > 0, f)
            child_slots = total + np.arange(len(frontier_ids) * f)
            parent_slots = np.repeat(frontier_slots, f)
            edges_src.append(np.where(valid, child_slots, dummy))
            edges_dst.append(parent_slots)
            node_ids.append(np.where(valid, nbrs.ravel(), 0))
            frontier_ids = np.where(valid, nbrs.ravel(), 0)
            frontier_slots = child_slots
            total += child_slots.size
        ids = np.concatenate(node_ids)
        n_real = len(ids)
        rr = _rng(self.seed, step, self.shard + 1000)
        features = np.zeros((self.n_pad, self.d_feat), np.float32)
        features[:n_real] = rr.normal(size=(n_real, self.d_feat)) * 0.1
        features[:n_real, 0] += (ids % 5 == 0)            # learnable signal
        positions = np.zeros((self.n_pad, 3), np.float32)
        positions[:n_real] = rr.normal(size=(n_real, 3))
        targets = np.zeros((self.n_pad, self.d_out), np.float32)
        targets[:n_real] = (ids[:, None] % 5 == 0)
        mask = np.zeros(self.n_pad, np.float32)
        mask[:self.batch_nodes] = 1.0                     # loss on seeds only
        src = np.concatenate(edges_src)[:self.e_pad]
        dst = np.concatenate(edges_dst)[:self.e_pad]
        return SampledSubgraph(features, positions,
                               src.astype(np.int32), dst.astype(np.int32),
                               targets, mask)


def molecule_batch(batch: int, n_atoms: int, n_edges: int, d_feat: int,
                   seed: int, step: int) -> Dict[str, np.ndarray]:
    """Batched small molecular graphs flattened into one disjoint graph."""
    r = _rng(seed, step, 0)
    n = batch * n_atoms
    positions = r.normal(size=(n, 3)).astype(np.float32) * 2
    features = r.normal(size=(n, d_feat)).astype(np.float32)
    src = np.concatenate([
        r.integers(0, n_atoms, n_edges) + b * n_atoms for b in range(batch)])
    dst = np.concatenate([
        r.integers(0, n_atoms, n_edges) + b * n_atoms for b in range(batch)])
    graph_ids = np.repeat(np.arange(batch), n_atoms)
    targets = r.normal(size=(batch, 1)).astype(np.float32)
    return dict(features=features, positions=positions,
                edge_src=src.astype(np.int32), edge_dst=dst.astype(np.int32),
                graph_ids=graph_ids.astype(np.int32),
                num_graphs=batch, targets=targets)
