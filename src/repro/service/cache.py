"""Deterministic result cache: (graph fingerprint, canonical spec) -> result.

Keys are SHA-256 over a canonical JSON encoding of the graph's content
fingerprint plus :meth:`DiscoveryRequest.canonical_spec`, so a repeated
query against unchanged data is served without touching the engine
(DESIGN.md §9.3).  Eviction is LRU with per-entry TTL expiry; the clock is
injectable so tests can drive expiry deterministically.
"""
from __future__ import annotations

import hashlib
import json
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional


def make_cache_key(graph_fingerprint: str, spec: Dict[str, Any]) -> str:
    """Deterministic cache key; `spec` must be JSON-serializable."""
    payload = json.dumps(
        {"graph": graph_fingerprint, "spec": spec},
        sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(payload.encode()).hexdigest()


class ResultCache:
    """LRU + TTL cache for discovery responses."""

    def __init__(self, capacity: int = 256, ttl_s: float = 3600.0,
                 clock: Callable[[], float] = time.monotonic):
        assert capacity >= 1
        self.capacity = capacity
        self.ttl_s = ttl_s
        self.clock = clock
        self._entries: "OrderedDict[str, tuple]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0     # capacity-driven LRU drops
        self.expirations = 0   # TTL-driven drops

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return self.peek(key) is not None

    def peek(self, key: str) -> Optional[Any]:
        """Like :meth:`get` but without touching hit/miss stats or LRU order."""
        entry = self._entries.get(key)
        if entry is None:
            return None
        value, stored_at = entry
        if self.clock() - stored_at > self.ttl_s:
            del self._entries[key]
            self.expirations += 1
            return None
        return value

    def get(self, key: str) -> Optional[Any]:
        entry = self._entries.get(key)
        if entry is not None:
            value, stored_at = entry
            if self.clock() - stored_at > self.ttl_s:
                del self._entries[key]
                self.expirations += 1
            else:
                self._entries.move_to_end(key)   # most recently used
                self.hits += 1
                return value
        self.misses += 1
        return None

    def put(self, key: str, value: Any) -> None:
        self._entries[key] = (value, self.clock())
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)    # least recently used
            self.evictions += 1

    def stats(self) -> Dict[str, int]:
        return dict(size=len(self._entries), hits=self.hits,
                    misses=self.misses, evictions=self.evictions,
                    expirations=self.expirations)
