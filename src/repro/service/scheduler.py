"""Multi-query scheduler + the discovery service facade (DESIGN.md §9.2).

The engine's super-step is pure per-query state-in/state-out
(:class:`repro.core.engine.EngineState`), so serving many concurrent
queries is a *scheduling* problem, not an engine problem: this module
round-robins super-steps across all live queries, giving every query
forward progress while long-running ones keep the device busy.  Each query
keeps its own device pool, result set, and VPQ, so interleaving cannot
change any query's answer — a scheduled query returns exactly what a
dedicated ``Engine.run()`` would (asserted in ``tests/test_service.py``).

``pattern`` queries run on the aggregate model (host-side group heap,
vectorized embedding extension); one scheduler step processes one group
pop, mirroring :func:`repro.core.aggregate.topk_frequent_patterns` exactly.
"""
from __future__ import annotations

import copy
import time
from typing import Dict, List, Optional


from repro.core.aggregate import TopKPatternMiner
from repro.core.engine import NEG, Engine
from repro.core.graph import GraphStore
from repro.obs import NOOP
from repro.runtime.fault_tolerance import StragglerMonitor

from .api import (DiscoveryRequest, DiscoveryResponse, GraphRegistry,
                  ValidationError, compile_request)
from .cache import ResultCache, make_cache_key


# ------------------------------------------------------------------- tasks
class EngineQueryTask:
    """One queue-driven query (clique / weighted-clique / iso) being stepped.

    ``engine`` may be shared across tasks with the identical compiled spec
    (the service's engine cache): all per-query search state lives in
    ``self.state``, so a shared engine only shares the jitted step —
    avoiding an XLA re-trace per request.
    """

    def __init__(self, request: DiscoveryRequest, engine: Engine,
                 obs=NOOP):
        self.request = request
        self.comp = engine.comp
        self.engine = engine
        # queue-wait attribution (DESIGN.md §16): time from admission to
        # this task's first scheduled step under the round-robin
        self._obs = obs
        self._admitted = time.perf_counter() if obs.enabled else 0.0
        self._started = False
        # durable runs (DESIGN.md §15): resume re-admits the query from the
        # newest committed checkpoint; checkpoint_every persists it as it
        # steps.  The restored state carries its step count, so the
        # remaining step_budget is honored exactly, and steps_at_admission
        # lets the service count only the steps *this* admission ran
        # (a restored query must not double-count its pre-crash steps in
        # engine_steps_total).
        self._mgr = None
        if request.checkpoint_dir and (request.checkpoint_every > 0
                                       or request.resume):
            from repro.checkpoint.manager import CheckpointManager
            self._mgr = CheckpointManager(request.checkpoint_dir)
        self.state = None
        if request.resume and self._mgr is not None and \
                self._mgr.latest_step() is not None:
            self.state = engine.resume(self._mgr)
        if self.state is None:
            self.state = engine.start()
        self.steps_at_admission = self.state.steps
        self._last_ckpt = self.state.steps
        # per-query slow-step watchdog: EMA step-time monitor, flagged
        # steps surfaced as stats["straggler_steps"]
        self.straggler = StragglerMonitor()
        self.terminated: Optional[str] = None
        self._payload: Optional[dict] = None
        if self.state.done:                 # a resumed, finished run
            self.terminated = "complete"
        elif self.state.steps >= request.step_budget:
            self.terminated = "step_budget"
        elif self._over_candidate_budget():  # seed frontier alone may exceed
            self.terminated = "candidate_budget"

    def _over_candidate_budget(self) -> bool:
        budget = self.request.candidate_budget
        return budget is not None and self.state.candidates >= budget

    @property
    def finished(self) -> bool:
        return self.terminated is not None

    def step(self) -> None:
        if self.finished:
            return
        # one scheduled step is one engine macro-step (steps_per_sync fused
        # super-steps); capping the fused count to the remaining budget
        # keeps step_budget truncation exact for any steps_per_sync
        t0 = time.perf_counter()
        if not self._started:
            self._started = True
            if self._obs.enabled:
                self._obs.histogram(
                    "service_queue_wait_seconds",
                    "admission-to-first-step wait under the scheduler"
                ).observe(t0 - self._admitted)
        self.engine.step(self.state,
                         max_inner=self.request.step_budget
                         - self.state.steps)
        self.straggler.record(self.state.steps, time.perf_counter() - t0)
        # budgets come from the request, not engine.cfg: the engine may be
        # shared with requests that differ only in budgets
        if self.state.done:
            self.terminated = "complete"
        elif self.state.steps >= self.request.step_budget:
            self.terminated = "step_budget"
        elif self._over_candidate_budget():
            self.terminated = "candidate_budget"
        if self._mgr is not None and self.request.checkpoint_every > 0 and \
                self.state.steps - self._last_ckpt >= \
                self.request.checkpoint_every:
            self.engine.save_checkpoint(self._mgr, self.state)
            self._last_ckpt = self.state.steps

    def finalize(self) -> dict:
        if self._payload is not None:
            return self._payload
        if self._mgr is not None and self.request.checkpoint_every > 0 \
                and self.state.steps > self._last_ckpt:
            # terminal state is restorable too (before finalize closes
            # the VPQ; the capture runs synchronously so close is safe)
            self.engine.save_checkpoint(self._mgr, self.state)
        res = self.engine.finalize(self.state)
        if self._mgr is not None:
            self._mgr.wait()
        results = []
        for i, key in enumerate(res.result_keys):
            if int(key) == int(NEG):
                continue   # empty result slot (fewer than k results exist)
            state_row = res.result_states[i]
            results.append(self.comp.describe(state_row)
                           if self.comp.describe else
                           [int(x) for x in state_row])
        self._payload = dict(
            workload=self.request.workload,
            result_keys=[int(x) for x in res.result_keys],
            results=results,
            stats=dict(steps=res.steps, candidates=res.candidates,
                       expanded=res.expanded, pruned=res.pruned,
                       spilled=res.spilled, refilled=res.refilled,
                       rebalanced=res.rebalanced,
                       late_pruned=res.late_pruned,
                       syncs=res.syncs, host_syncs=res.host_syncs,
                       straggler_steps=self.straggler.straggler_steps),
            terminated=self.terminated or "complete")
        return self._payload


class PatternQueryTask:
    """Top-k frequent-pattern query, stepped one group pop at a time.

    Thin budget/termination wrapper over
    :class:`repro.core.aggregate.TopKPatternMiner` — the same
    implementation :func:`~repro.core.aggregate.topk_frequent_patterns`
    runs to completion, so prioritization/pruning order cannot diverge
    between scheduled and library runs.  Budget early-termination is a
    service-level concern enforced here (inclusive, like the engine task),
    not inside the miner.
    """

    def __init__(self, req: DiscoveryRequest, graph: GraphStore,
                 obs=NOOP):
        self.request = req
        self._obs = obs
        self._admitted = time.perf_counter() if obs.enabled else 0.0
        self._started = False
        # the miner keeps its library-default runaway cap; the service
        # budget is enforced here, between steps, with the same inclusive
        # (>=) semantics as EngineQueryTask for every workload
        self.miner = TopKPatternMiner(graph, req.m_edges, req.k,
                                      use_pallas=req.use_pallas,
                                      interpret=req.interpret,
                                      predicate=req.predicate(),
                                      label_filter=req.label_filter)
        self.straggler = StragglerMonitor()
        self.terminated: Optional[str] = (
            "complete" if self.miner.done else None)
        self._payload: Optional[dict] = None
        if not self.finished and self._over_candidate_budget():
            self.terminated = "candidate_budget"   # seed embeddings alone

    def _over_candidate_budget(self) -> bool:
        budget = self.request.candidate_budget
        return budget is not None and self.miner.candidates >= budget

    @property
    def finished(self) -> bool:
        return self.terminated is not None

    def step(self) -> None:
        if self.finished:
            return
        t0 = time.perf_counter()
        if not self._started:
            self._started = True
            if self._obs.enabled:
                self._obs.histogram(
                    "service_queue_wait_seconds",
                    "admission-to-first-step wait under the scheduler"
                ).observe(t0 - self._admitted)
        self.miner.step()
        self.straggler.record(self.miner.steps, time.perf_counter() - t0)
        if self.miner.done:
            self.terminated = ("complete" if self.miner.completed
                               else "candidate_budget")
        elif self._over_candidate_budget():
            self.terminated = "candidate_budget"
        elif self.miner.steps >= self.request.step_budget:
            self.terminated = "step_budget"

    def finalize(self) -> dict:
        if self._payload is not None:
            return self._payload
        res = self.miner.result()
        self._payload = dict(
            workload="pattern",
            result_keys=[sup for sup, _ in res.patterns],
            results=[[list(edge) for edge in code]
                     for _, code in res.patterns],
            stats=dict(steps=self.miner.steps, candidates=res.candidates,
                       expanded=res.groups_expanded,
                       pruned=res.groups_pruned, spilled=0, refilled=0,
                       rebalanced=0, late_pruned=0,
                       straggler_steps=self.straggler.straggler_steps),
            terminated=self.terminated or "complete")
        return self._payload


# --------------------------------------------------------------- scheduler
class QueryScheduler:
    """Round-robins engine steps across live queries.

    ``slice_steps`` is the number of consecutive engine steps a query gets
    per scheduling turn — 1 is fair round-robin; larger values amortize
    host-side scheduling overhead at the cost of per-query latency spread.
    When a request sets ``steps_per_sync = T > 1`` each scheduled step is
    one fused *macro*-step of up to T super-steps (DESIGN.md §13), so a
    slice covers up to ``slice_steps * T`` super-steps — the two knobs
    compose: slices amortize scheduling, macro-steps amortize dispatch.
    """

    def __init__(self, slice_steps: int = 1):
        assert slice_steps >= 1
        self.slice_steps = slice_steps

    def drive(self, tasks: List) -> None:
        """Step all tasks to completion, interleaved."""
        live = [t for t in tasks if not t.finished]
        while live:
            for task in live:
                for _ in range(self.slice_steps):
                    task.step()
                    if task.finished:
                        break
            live = [t for t in live if not t.finished]


# ----------------------------------------------------------------- service
class DiscoveryService:
    """Request validation -> cache lookup -> scheduled execution -> response.

    The unit of service work is a *batch* of requests (:meth:`serve`): all
    cache misses in the batch run concurrently under one
    :class:`QueryScheduler`.  ``engine_steps_total`` counts every engine
    super-step executed on behalf of this service — cache hits add zero.
    """

    def __init__(self, registry: Optional[GraphRegistry] = None,
                 cache: Optional[ResultCache] = None,
                 slice_steps: int = 1, engine_cache_size: int = 32,
                 observability=None):
        self.registry = registry or GraphRegistry()
        self.cache = cache or ResultCache()
        self.scheduler = QueryScheduler(slice_steps=slice_steps)
        # compiled-engine reuse: identical specs (same cache key) share one
        # Engine and therefore one XLA trace of the super-step; all search
        # state is per-task (EngineState), so sharing is safe even within
        # a batch.  LRU-bounded; TTL is irrelevant for compiled code.
        self._engines = ResultCache(capacity=engine_cache_size,
                                    ttl_s=float("inf"))
        self.engine_steps_total = 0
        self.requests_served = 0
        # observability (DESIGN.md §16): one shared registry for service
        # counters AND (via _make_task injection) the engines of observe=
        # True requests, so /metrics answers for the whole stack at once
        self.obs = observability if observability is not None else NOOP
        self._m_requests = self.obs.counter(
            "service_requests_total", "requests received")
        self._m_cache_hits = self.obs.counter(
            "service_cache_hits_total", "result-cache hits")
        self._m_cache_misses = self.obs.counter(
            "service_cache_misses_total",
            "result-cache misses (executed queries)")
        self._m_validation_errors = self.obs.counter(
            "service_validation_errors_total", "rejected requests")
        self._m_engine_steps = self.obs.counter(
            "service_engine_steps_total",
            "engine super-steps run on behalf of this service")
        self._h_request = self.obs.histogram(
            "service_request_seconds", "per-request wall time")

    def register_graph(self, name: str, graph) -> None:
        self.registry.register(name, graph)

    # ------------------------------------------------------------ serving
    def serve(self, requests: List[DiscoveryRequest]
              ) -> List[DiscoveryResponse]:
        """Serve a batch; responses come back in request order."""
        t0 = time.perf_counter()
        self._m_requests.inc(len(requests))
        responses: List[Optional[DiscoveryResponse]] = [None] * len(requests)
        pending: List[tuple] = []      # (indices, cache_key|None, task)
        by_key: Dict[str, tuple] = {}  # within-batch dedup of identical specs

        for i, req in enumerate(requests):
            try:
                # validate only — lowering to a computation is deferred to
                # cache misses, so a cache hit costs no compile work
                graph = req.validate(self.registry)
                key = make_cache_key(graph.fingerprint, req.canonical_spec())
                if req.use_cache:
                    payload = self.cache.get(key)
                    if payload is not None:
                        self._m_cache_hits.inc()
                        lat = time.perf_counter() - t0
                        self._h_request.observe(lat)
                        responses[i] = self._payload_to_response(
                            req, payload, cached=True, latency_s=lat)
                        continue
                    if key in by_key:  # identical spec already in this batch
                        by_key[key][0].append(i)
                        continue
                entry = ([i], key if req.use_cache else None,
                         self._make_task(req, graph))
                self._m_cache_misses.inc()
            except (TypeError, ValueError) as e:
                # ValidationError and any mistyped field the validators
                # trip over: reject this request, keep serving the batch
                self._m_validation_errors.inc()
                responses[i] = DiscoveryResponse(
                    request_id=req.request_id, workload=str(req.workload),
                    status="error", error=str(e))
                continue
            pending.append(entry)
            if req.use_cache:
                by_key[key] = entry

        with self.obs.span("service.drive"):
            self.scheduler.drive([task for _, _, task in pending])

        for indices, key, task in pending:
            payload = task.finalize()
            if isinstance(task, EngineQueryTask):
                # count only the steps this admission actually ran: a
                # resumed state arrives carrying its pre-crash step count
                ran = task.state.steps - task.steps_at_admission
                self.engine_steps_total += ran
                self._m_engine_steps.inc(ran)
            if key is not None:
                self.cache.put(key, payload)
            for j, i in enumerate(indices):
                if j > 0:   # within-batch dedup joins are cache hits too
                    self._m_cache_hits.inc()
                lat = time.perf_counter() - t0
                self._h_request.observe(lat)
                responses[i] = self._payload_to_response(
                    requests[i], payload, cached=j > 0, latency_s=lat)

        self.requests_served += len(requests)
        return responses   # type: ignore[return-value]

    def query(self, request: DiscoveryRequest) -> DiscoveryResponse:
        """Single-request convenience wrapper around :meth:`serve`."""
        return self.serve([request])[0]

    def _make_task(self, req: DiscoveryRequest, graph: GraphStore):
        if req.workload == "pattern":
            return PatternQueryTask(req, graph, obs=self.obs)
        # the engine key covers only what shapes the compiled step: budgets
        # are enforced per-task (so they're dropped from the spec), while
        # use_pallas/interpret/steps_per_sync/sync_every change the
        # compiled step without changing complete-run results (so they're
        # added back — all four are deliberately absent from the
        # result-cache key; shards is already in the spec).  The checkpoint
        # knobs join them: they ride EngineConfig (Engine.run reads them),
        # so tasks sharing an engine must share its checkpoint policy —
        # and two queries writing different checkpoint_dirs must not share
        # one engine object (DESIGN.md §15).
        engine_spec = req.canonical_spec()
        engine_spec.pop("step_budget", None)
        engine_spec.pop("candidate_budget", None)
        engine_spec["use_pallas"] = req.use_pallas
        engine_spec["interpret"] = req.interpret
        engine_spec["steps_per_sync"] = req.steps_per_sync
        engine_spec["sync_every"] = req.sync_every
        engine_spec["checkpoint_every"] = req.checkpoint_every
        engine_spec["checkpoint_dir"] = req.checkpoint_dir
        engine_spec["observe"] = req.observe
        engine_key = make_cache_key(graph.fingerprint, engine_spec)
        engine = self._engines.get(engine_key)
        if engine is None:
            compiled = compile_request(req, self.registry, graph=graph)
            if req.observe and self.obs.enabled:
                # observing engines record into the service registry so a
                # single snapshot covers the whole process (DESIGN.md §16)
                compiled.engine_cfg.observability = self.obs
            if compiled.engine_cfg.shards > 1:
                from repro.distributed import ShardedEngine
                engine = ShardedEngine(compiled.comp, compiled.engine_cfg)
            else:
                engine = Engine(compiled.comp, compiled.engine_cfg)
            self._engines.put(engine_key, engine)
        return EngineQueryTask(req, engine, obs=self.obs)

    @staticmethod
    def _payload_to_response(req: DiscoveryRequest, payload: dict,
                             cached: bool, latency_s: float
                             ) -> DiscoveryResponse:
        # deep copy so callers mutating a response (or its nested result
        # lists) cannot corrupt the cached payload or sibling responses
        payload = copy.deepcopy(payload)
        return DiscoveryResponse(
            request_id=req.request_id, workload=payload["workload"],
            status="ok", result_keys=payload["result_keys"],
            results=payload["results"], stats=payload["stats"],
            terminated=payload["terminated"], cached=cached,
            latency_s=latency_s)
