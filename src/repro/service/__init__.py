"""Multi-query discovery service on the Nuri engine (DESIGN.md §9).

Layers, bottom-up:

* :mod:`repro.service.api` — :class:`DiscoveryRequest` /
  :class:`DiscoveryResponse`, validation, the graph registry, and the
  compile step onto :class:`repro.core.api.SubgraphComputation`;
* :mod:`repro.service.cache` — deterministic LRU+TTL result cache keyed by
  (graph fingerprint, canonical query spec);
* :mod:`repro.service.scheduler` — per-query tasks, the round-robin
  super-step scheduler, and the :class:`DiscoveryService` facade.
"""
from .api import (DiscoveryRequest, DiscoveryResponse, GraphRegistry,
                  ValidationError, WORKLOADS, compile_request)
from .cache import ResultCache, make_cache_key
from .scheduler import DiscoveryService, QueryScheduler

__all__ = [
    "DiscoveryRequest", "DiscoveryResponse", "GraphRegistry",
    "ValidationError", "WORKLOADS", "compile_request",
    "ResultCache", "make_cache_key",
    "DiscoveryService", "QueryScheduler",
]
