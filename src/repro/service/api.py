"""Discovery-service request/response schema (DESIGN.md §9, docs/API.md).

A :class:`DiscoveryRequest` is a declarative query spec — workload, graph
handle, ``k``, and budgets — that :func:`compile_request` turns into the
engine-facing form: a :class:`repro.core.api.SubgraphComputation` plus an
:class:`repro.core.engine.EngineConfig` for the queue-driven workloads
(clique / weighted-clique / iso), or an aggregate-model mining task for
``pattern``.  Validation happens eagerly at submit time so malformed
queries are rejected before any device work, mirroring the query-driven
front-end of Dasgupta & Gupta (arXiv:2102.09120).

Graphs are referred to by *handle* (a registry name), never shipped inline;
the registry resolves handles to :class:`repro.core.graph.GraphStore` and
exposes each graph's content :attr:`~repro.core.graph.GraphStore.fingerprint`
for cache keying.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import EngineConfig
from repro.core.graph import GraphStore
from repro.core.labels import LABEL_FILTERS, LabelPredicate

from .cache import ResultCache

WORKLOADS = ("clique", "weighted-clique", "iso", "pattern")


class ValidationError(ValueError):
    """A malformed :class:`DiscoveryRequest` (rejected before execution)."""


class GraphRegistry:
    """Named graph handles -> :class:`GraphStore` (the service's data tier)."""

    def __init__(self):
        self._graphs: Dict[str, GraphStore] = {}

    def register(self, name: str, graph: GraphStore) -> None:
        if not isinstance(graph, GraphStore):
            raise TypeError(f"{name}: expected a GraphStore")
        self._graphs[name] = graph

    def get(self, name: str) -> GraphStore:
        if name not in self._graphs:
            raise ValidationError(
                f"unknown graph handle {name!r}; registered: "
                f"{sorted(self._graphs)}")
        return self._graphs[name]

    def __contains__(self, name: str) -> bool:
        return name in self._graphs

    def names(self) -> List[str]:
        return sorted(self._graphs)


@dataclasses.dataclass(frozen=True)
class DiscoveryRequest:
    """One top-k discovery query (fields documented in docs/API.md)."""

    graph: str                        # registry handle
    workload: str                     # clique | weighted-clique | iso | pattern
    k: int = 1
    # budgets / execution knobs
    batch: int = 64                   # B: states dequeued per super-step
    pool_capacity: int = 4096         # C: device pool slots
    step_budget: int = 100_000        # max engine super-steps for this query
    candidate_budget: Optional[int] = None  # max subgraphs materialized
    # workload-specific parameters
    weights: Optional[Tuple[int, ...]] = None             # weighted-clique
    q_edges: Optional[Tuple[Tuple[int, int], ...]] = None  # iso query graph
    q_labels: Optional[Tuple[int, ...]] = None             # iso query labels
    induced: bool = True                                   # iso semantics
    max_hops: int = 2                                      # iso index depth
    m_edges: Optional[int] = None                          # pattern size
    # label-constrained discovery (iso / pattern; DESIGN.md §12):
    # label_predicate is a spec dict with any of `vertex_any_of` (allowed
    # vertex labels), `q_any_of` (per-query-vertex label classes, iso
    # only), `edge_any_of` (allowed edge types; needs a graph with edge
    # labels).  label_filter places the vertex predicate: "pushdown"
    # folds it into the kernel constraint mask + priority index (default),
    # "post" filters after candidate materialization (the host-side
    # baseline).  Complete runs are byte-identical across modes, but
    # budget-truncated runs are not — so BOTH fields join the result-cache
    # key (canonicalized), like batch/pool_capacity/shards.
    label_predicate: Optional[Dict[str, Any]] = None
    label_filter: str = "pushdown"
    # kernel-path knobs (all workloads; byte-identical results, so both
    # are excluded from the result-cache key — DESIGN.md §10)
    use_pallas: bool = False          # Pallas masked-intersection path
    interpret: Optional[bool] = None  # None = auto-detect backend
    # macro-stepping (engine workloads; DESIGN.md §13): number of engine
    # super-steps fused into one jitted device loop per host sync.
    # Complete runs are byte-identical for any value (parity-tested), and
    # step_budget truncation lands on the same step count for any value
    # (the fused loop is capped to the remaining budget) — so like
    # use_pallas/interpret it is EXCLUDED from the result-cache key.
    # Truncated-run caveats (documented in docs/API.md): candidate_budget
    # is still checked between host syncs, so a fused run can overshoot
    # it by up to T-1 super-steps of candidates, and a truncated run's
    # partial answer can differ across values in spill tie-order.
    # Ignored by `pattern` (host-side aggregate model, no engine loop).
    steps_per_sync: int = 1
    # staleness-tolerant bound exchange (sharded engine; DESIGN.md §14):
    # number of shard-local inner steps between §4 bound-exchange
    # all-gathers.  Between exchanges shards prune against the
    # last-exchanged global bound (max'd with the fresh local k-th best),
    # which is only ever looser than per-step exchange — complete runs
    # are byte-identical for any value (parity-tested), so like
    # steps_per_sync it is EXCLUDED from the result-cache key but part of
    # the engine-reuse key (it changes the compiled macro loop).  Ignored
    # by single-device runs (shards == 1 still accepts it — the 1-shard
    # engine amortizes its degenerate self-exchange) and by `pattern`.
    sync_every: int = 1
    # device-mesh sharding (engine workloads; DESIGN.md §11).  shards > 1
    # runs the query on the sharded multi-device engine with batch /
    # pool_capacity as per-shard shapes.  Complete runs are byte-identical
    # for any shard count (parity-tested), but budget-truncated runs are
    # not — so like batch/pool_capacity (and unlike the kernel knobs) it
    # is part of the result-cache key.
    shards: int = 1
    # durable runs (engine workloads; DESIGN.md §15): checkpoint_every =
    # N > 0 persists the query's engine state to checkpoint_dir at the
    # first host-sync boundary every >= N steps, through the atomic-commit
    # protocol; resume=True re-admits the query from the newest committed
    # step there (fresh start when none exists), with the remaining
    # step_budget honored exactly — the restored state carries its step
    # count, so budget truncation lands on the same total step count as an
    # uninterrupted run.  Checkpoints are pure observers (a resumed
    # complete run is byte-identical — crash-proved in
    # tests/test_fault_injection.py), so like use_pallas/steps_per_sync
    # both knobs are EXCLUDED from the result-cache key; they ARE part of
    # the engine-reuse key (tasks sharing an engine share its checkpoint
    # policy via EngineConfig).
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None
    resume: bool = False
    # observability (DESIGN.md §16): observe=True routes this query's
    # engine metrics/spans into the service's live Observability (or a
    # private one for direct compile_request callers).  A pure observer
    # like checkpointing — results are byte-identical either way
    # (parity-tested in tests/test_obs.py) — so it is EXCLUDED from the
    # result-cache key but part of the engine-reuse key.
    observe: bool = False
    # service knobs
    use_cache: bool = True
    request_id: Optional[str] = None

    # ------------------------------------------------------------- building
    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "DiscoveryRequest":
        """Build from a JSON-decoded dict (lists become tuples)."""
        d = dict(d)
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValidationError(f"unknown request fields: {sorted(unknown)}")
        try:
            for f in ("k", "batch", "pool_capacity", "step_budget",
                      "candidate_budget", "max_hops", "m_edges", "shards",
                      "steps_per_sync", "sync_every", "checkpoint_every"):
                if d.get(f) is not None:
                    d[f] = int(d[f])
            for f in ("induced", "use_pallas", "use_cache", "interpret",
                      "resume", "observe"):
                if d.get(f) is not None:
                    d[f] = bool(d[f])
            if d.get("label_filter") is not None:
                d["label_filter"] = str(d["label_filter"])
            if d.get("checkpoint_dir") is not None:
                d["checkpoint_dir"] = str(d["checkpoint_dir"])
            if d.get("weights") is not None:
                d["weights"] = tuple(int(w) for w in d["weights"])
            if d.get("q_edges") is not None:
                d["q_edges"] = tuple((int(a), int(b)) for a, b in d["q_edges"])
            if d.get("q_labels") is not None:
                d["q_labels"] = tuple(int(l) for l in d["q_labels"])
        except (TypeError, ValueError) as e:
            raise ValidationError(f"malformed request field: {e}") from e
        return cls(**d)

    # ----------------------------------------------------------- validation
    def validate(self, registry: GraphRegistry) -> GraphStore:
        """Check the spec against the registry; returns the resolved graph."""
        if self.workload not in WORKLOADS:
            raise ValidationError(
                f"workload must be one of {WORKLOADS}, got {self.workload!r}")
        if self.k <= 0:
            raise ValidationError(f"k must be >= 1, got {self.k}")
        if self.batch <= 0:
            raise ValidationError(f"batch must be >= 1, got {self.batch}")
        if self.pool_capacity < self.batch:
            raise ValidationError(
                f"pool_capacity ({self.pool_capacity}) must be >= batch "
                f"({self.batch})")
        if self.step_budget <= 0:
            raise ValidationError(
                f"step_budget must be >= 1, got {self.step_budget}")
        if self.candidate_budget is not None and self.candidate_budget <= 0:
            raise ValidationError(
                f"candidate_budget must be >= 1, got {self.candidate_budget}")
        if self.shards < 1:
            raise ValidationError(f"shards must be >= 1, got {self.shards}")
        if self.steps_per_sync < 1:
            raise ValidationError(
                f"steps_per_sync must be >= 1, got {self.steps_per_sync}")
        if self.sync_every < 1:
            raise ValidationError(
                f"sync_every must be >= 1, got {self.sync_every}")
        if self.shards > 1 and self.workload == "pattern":
            raise ValidationError(
                "shards > 1 applies to engine workloads only; pattern "
                "mining runs on the host-side aggregate model "
                "(DESIGN.md §11)")
        if self.checkpoint_every < 0:
            raise ValidationError(
                f"checkpoint_every must be >= 0, got {self.checkpoint_every}")
        if self.checkpoint_every > 0 and not self.checkpoint_dir:
            raise ValidationError(
                "checkpoint_every > 0 requires `checkpoint_dir`")
        if self.resume and not self.checkpoint_dir:
            raise ValidationError("resume requires `checkpoint_dir`")
        if (self.checkpoint_every > 0 or self.resume) and \
                self.workload == "pattern":
            raise ValidationError(
                "checkpoint/resume applies to engine workloads only; "
                "pattern mining runs on the host-side aggregate model "
                "(DESIGN.md §15)")
        g = registry.get(self.graph)

        if self.workload == "weighted-clique":
            if self.use_pallas:
                # the weighted CP bound is a *weighted* popcount, which the
                # masked-intersection kernel does not compute — reject
                # explicitly rather than silently running the reference path
                raise ValidationError(
                    "use_pallas is not supported for weighted-clique "
                    "(needs a weighted-popcount kernel variant; "
                    "DESIGN.md §10)")
            if self.weights is None:
                raise ValidationError("weighted-clique requires `weights`")
            if len(self.weights) != g.n:
                raise ValidationError(
                    f"weights has {len(self.weights)} entries for an "
                    f"{g.n}-vertex graph")
            if any(w <= 0 for w in self.weights):
                raise ValidationError("weights must be positive integers")
        elif self.workload == "iso":
            if self.q_edges is None or self.q_labels is None:
                raise ValidationError("iso requires `q_edges` and `q_labels`")
            if g.labels is None:
                raise ValidationError(
                    f"iso requires a labeled graph; {self.graph!r} is "
                    "unlabeled")
            nq = len(self.q_labels)
            if nq == 0:
                raise ValidationError("iso query graph is empty")
            for a, b in self.q_edges:
                if not (0 <= a < nq and 0 <= b < nq) or a == b:
                    raise ValidationError(
                        f"iso query edge ({a}, {b}) out of range for "
                        f"{nq} query vertices")
            if self.max_hops <= 0:
                raise ValidationError(
                    f"max_hops must be >= 1, got {self.max_hops}")
        elif self.workload == "pattern":
            if self.m_edges is None or self.m_edges <= 0:
                raise ValidationError(
                    "pattern requires `m_edges` >= 1")
            if g.labels is None:
                raise ValidationError(
                    f"pattern mining requires a labeled graph; "
                    f"{self.graph!r} is unlabeled")

        if self.label_filter not in LABEL_FILTERS:
            raise ValidationError(
                f"label_filter must be one of {LABEL_FILTERS}, got "
                f"{self.label_filter!r}")
        if self.label_predicate is not None:
            if self.workload not in ("iso", "pattern"):
                raise ValidationError(
                    f"label_predicate applies to iso/pattern only, not "
                    f"{self.workload!r}")
            try:
                pred = LabelPredicate.from_spec(self.label_predicate)
                if pred is not None:
                    pred.validate(g, self.workload,
                                  nq=(len(self.q_labels)
                                      if self.workload == "iso" else None))
            except ValueError as e:
                raise ValidationError(str(e)) from e
        return g

    def predicate(self) -> Optional[LabelPredicate]:
        """The parsed, canonical :class:`LabelPredicate` (None when the
        spec is absent or trivial).  Raises ``ValidationError`` on a
        malformed spec — call after/with :meth:`validate`.

        Parsed once per request (memoized via ``__dict__``, the
        cached_property idiom — validate, cache keying, engine keying,
        and compilation all consume the same parse).
        """
        if "_pred_cache" not in self.__dict__:
            try:
                pred = LabelPredicate.from_spec(self.label_predicate)
            except ValueError as e:
                raise ValidationError(str(e)) from e
            self.__dict__["_pred_cache"] = pred
        return self.__dict__["_pred_cache"]

    # -------------------------------------------------------- canonical form
    def canonical_spec(self) -> Dict[str, Any]:
        """Canonical, JSON-stable dict of everything that determines the
        *result* of this request — the cache-key payload.

        Excludes ``use_cache`` and ``request_id`` (service plumbing), the
        kernel-path knobs ``use_pallas`` / ``interpret``
        (parity-tested to leave results byte-identical *per step*, so
        kernel- and reference-path runs of the same query share one cache
        entry), ``steps_per_sync`` (DESIGN.md §13: complete runs are
        byte-identical for any fusion depth and budget truncation lands
        on the same step count, so fused and unfused runs of the same
        query share one cache entry too), ``sync_every`` for the same
        reason (DESIGN.md §14: a stale bound is only ever looser, so
        complete runs are byte-identical for any exchange cadence — both
        knobs remain part of the engine-reuse key, which they DO change),
        and the checkpoint knobs ``checkpoint_every`` / ``checkpoint_dir``
        / ``resume`` (DESIGN.md §15: checkpoints are pure observers and a
        resumed run is byte-identical to an uninterrupted one, so
        checkpointed, resumed, and plain runs of the same query share one
        cache entry; the first two join the engine-reuse key — tasks
        sharing an engine share its checkpoint policy).  ``observe`` is
        excluded by the same pure-observer discipline (DESIGN.md §16:
        metrics and spans never touch the step trajectory — parity-tested
        in tests/test_obs.py), so instrumented and plain runs of the same
        query share one cache entry; it joins the engine-reuse key.
        ``shards`` IS included, like
        ``batch``/``pool_capacity``:
        complete runs are shard-count invariant, but a run truncated by
        ``step_budget``/``candidate_budget`` is not, and the cache key
        cannot know at lookup time which case a payload is.  Query edges
        are normalized
        to sorted ``(min, max)`` pairs so isomorphic edge orderings of the
        same query graph key identically.  A label predicate enters in
        its canonical form (sorted, deduplicated label sets) together
        with ``label_filter`` — pushdown and post are byte-identical only
        for complete runs, the same reason ``shards`` is keyed; a trivial
        predicate (absent or empty spec) adds nothing, so constrained and
        unconstrained requests never collide.
        """
        spec: Dict[str, Any] = dict(
            workload=self.workload, k=self.k, batch=self.batch,
            pool_capacity=self.pool_capacity, shards=self.shards,
            step_budget=self.step_budget,
            candidate_budget=self.candidate_budget)
        pred = self.predicate()
        if pred is not None:
            spec["label_predicate"] = pred.canonical()
            spec["label_filter"] = self.label_filter
        if self.workload == "weighted-clique":
            spec["weights"] = list(self.weights)
        elif self.workload == "iso":
            spec["q_edges"] = sorted(
                [min(a, b), max(a, b)] for a, b in self.q_edges)
            spec["q_labels"] = list(self.q_labels)
            spec["induced"] = self.induced
            spec["max_hops"] = self.max_hops
        elif self.workload == "pattern":
            spec["m_edges"] = self.m_edges
        return spec


@dataclasses.dataclass
class DiscoveryResponse:
    """Service reply: top-k results plus execution accounting."""

    request_id: Optional[str]
    workload: str
    status: str                       # "ok" | "error"
    result_keys: List[int] = dataclasses.field(default_factory=list)
    results: List[Any] = dataclasses.field(default_factory=list)
    stats: Dict[str, int] = dataclasses.field(default_factory=dict)
    terminated: str = "complete"      # complete | step_budget | candidate_budget
    cached: bool = False
    latency_s: float = 0.0
    error: Optional[str] = None

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)


# ------------------------------------------------------------------ compile
@dataclasses.dataclass(frozen=True)
class CompiledQuery:
    """A validated request lowered to its executable form."""

    request: DiscoveryRequest
    graph: GraphStore
    kind: str                                     # "engine" | "aggregate"
    comp: Optional[object] = None                 # SubgraphComputation
    engine_cfg: Optional[EngineConfig] = None


# per-(graph fingerprint, max_hops, allowed edge types) iso index cache:
# building the Fig.-7 index is a dense-matmul preprocessing pass,
# amortized across requests.  Edge-type predicates need an index built on
# the restricted adjacency (full-graph hop distances would be unsound —
# see build_iso_index), hence the extra key component; vertex predicates
# reuse the unrestricted index (restriction happens at bound-assembly
# time inside make_iso_computation).  LRU-bounded so long-lived services
# that cycle graphs don't leak indexes.
_ISO_INDEX_CACHE = ResultCache(capacity=16, ttl_s=float("inf"))


def _iso_index(g: GraphStore, max_hops: int,
               predicate: Optional[LabelPredicate]) -> np.ndarray:
    from repro.core.iso import build_iso_index
    etypes = (",".join(map(str, predicate.edge_any_of))
              if predicate is not None and predicate.edge_any_of is not None
              else "")
    key = f"{g.fingerprint}:{max_hops}:{etypes}"
    index = _ISO_INDEX_CACHE.get(key)
    if index is None:
        index = build_iso_index(g, max_hops, predicate=predicate)
        _ISO_INDEX_CACHE.put(key, index)
    return index


def compile_request(req: DiscoveryRequest, registry: GraphRegistry,
                    graph: Optional[GraphStore] = None) -> CompiledQuery:
    """Validate and lower a request onto the core computational models.

    ``graph`` short-circuits validation when the caller has already run
    :meth:`DiscoveryRequest.validate` (the service's serve loop does).
    """
    g = graph if graph is not None else req.validate(registry)
    if req.workload == "pattern":
        return CompiledQuery(request=req, graph=g, kind="aggregate")

    # EngineConfig is the single carrier of the kernel-path knobs: the
    # computation constructors below read them from here, so engine-driven
    # callers (service, benchmarks) select the kernel path per request
    cfg = EngineConfig(k=req.k, batch=req.batch,
                       pool_capacity=req.pool_capacity,
                       max_steps=req.step_budget, shards=req.shards,
                       steps_per_sync=req.steps_per_sync,
                       sync_every=req.sync_every,
                       checkpoint_every=req.checkpoint_every,
                       checkpoint_dir=req.checkpoint_dir,
                       use_pallas=req.use_pallas, interpret=req.interpret,
                       observe=req.observe)

    if req.workload == "clique":
        from repro.core.clique import make_clique_computation
        comp = make_clique_computation(g, use_pallas=cfg.use_pallas,
                                       interpret=cfg.interpret)
    elif req.workload == "weighted-clique":
        from repro.core.weighted_clique import make_weighted_clique_computation
        comp = make_weighted_clique_computation(
            g, np.asarray(req.weights, np.int32))
    else:  # iso
        from repro.core.iso import make_iso_computation
        pred = req.predicate()
        comp = make_iso_computation(
            g, list(req.q_edges), list(req.q_labels),
            _iso_index(g, req.max_hops, pred), induced=req.induced,
            use_pallas=cfg.use_pallas, interpret=cfg.interpret,
            predicate=pred, label_filter=req.label_filter)

    return CompiledQuery(request=req, graph=g, kind="engine",
                         comp=comp, engine_cfg=cfg)
