"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ``(data, model)``.
Multi-pod:  2 x 16 x 16 = 512 chips, axes ``(pod, data, model)`` — the
``pod`` axis composes with ``data`` for gradient reduction (reduce-scatter
within pod over ICI, cross-pod all-reduce over DCN), expressed to GSPMD by
sharding the batch over ``('pod', 'data')``.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh

try:                                    # jax >= 0.5 explicit-sharding API
    from jax.sharding import AxisType
except ImportError:                     # older jax: meshes are Auto-typed
    AxisType = None


def _make_mesh(devices: np.ndarray, axes) -> Mesh:
    if AxisType is None:
        return Mesh(devices, axes)
    return Mesh(devices, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)}. "
            "Run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this) or on real hardware.")
    return _make_mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh() -> Mesh:
    """1x1 mesh over the local device — used by smoke tests and examples."""
    return _make_mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                      ("data", "model"))
