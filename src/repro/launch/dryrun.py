import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Do not set this flag globally — smoke tests and
# benchmarks must see 1 device.

"""Multi-pod dry-run: ``lower().compile()`` every (architecture × input
shape) cell on the production meshes and record the compiled artifacts'
memory/cost/collective profile.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # 2-pod only

Artifacts: ``artifacts/dryrun/<mesh>/<arch>__<shape>.json`` with per-device
HLO FLOPs, bytes accessed, peak memory, and collective bytes by op type —
the inputs to :mod:`repro.analysis.roofline`.
"""
import argparse
import json
import re
import time
import traceback


_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_TYPE_RE = re.compile(r"\b(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|"
                      r"u8|pred)\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
          "pred": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _BYTES.get(dtype, 1 if dtype.startswith("f8") else 4)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum *operand* bytes of every collective op in the partitioned HLO.

    Each HLO instruction line prints operand types inline, e.g.
    ``x = f32[2048,128] all-gather(f32[128,128] y), ...`` — the first typed
    shape is the result, the rest are operands.
    """
    out = {}
    done_ops = 0
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if f"{kind}-done" in line:
            done_ops += 1
            continue                      # operand counted at -start
        shapes = _TYPE_RE.findall(line)
        if not shapes:
            continue
        operands = shapes[1:] or shapes   # skip result shape
        nbytes = sum(_shape_bytes(t, d) for t, d in operands)
        d = out.setdefault(kind, {"count": 0, "operand_bytes": 0})
        d["count"] += 1
        d["operand_bytes"] += nbytes
    return out


def _measure(compiled) -> dict:
    ca = compiled.cost_analysis()
    return dict(
        flops=float(ca.get("flops", 0.0)),
        transcendentals=float(ca.get("transcendentals", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collectives=parse_collective_bytes(compiled.as_text()),
    )


def _extrapolate(p1: dict, p2: dict, l1: int, l2: int, l_full: int) -> dict:
    """Affine trip-count correction: total(L) = f(l1) + (L-l1)·Δ/(l2-l1)."""
    def lin(a, b):
        per = (b - a) / (l2 - l1)
        return max(0.0, a + (l_full - l1) * per)

    out = dict(
        flops=lin(p1["flops"], p2["flops"]),
        transcendentals=lin(p1["transcendentals"], p2["transcendentals"]),
        bytes_accessed=lin(p1["bytes_accessed"], p2["bytes_accessed"]),
    )
    colls = {}
    kinds = set(p1["collectives"]) | set(p2["collectives"])
    for k in kinds:
        a = p1["collectives"].get(k, {"count": 0, "operand_bytes": 0})
        b = p2["collectives"].get(k, {"count": 0, "operand_bytes": 0})
        colls[k] = dict(
            count=int(round(lin(a["count"], b["count"]))),
            operand_bytes=int(lin(a["operand_bytes"], b["operand_bytes"])))
    out["collectives"] = colls
    return out


def _fit_layers_edges(m: dict, l1: int, l2: int, ep: int,
                      l_full: int, e_full: int) -> dict:
    """Solve f(L,E) = a0 + a1·E + L·c + L·d·E from 4 probe points and
    evaluate at (l_full, e_full)."""
    dl = l2 - l1

    def fit(g):
        f11, f21 = g(m[(l1, ep)]), g(m[(l2, ep)])
        f12, f22 = g(m[(l1, 2 * ep)]), g(m[(l2, 2 * ep)])
        d = (f22 - f21 - f12 + f11) / (ep * dl)
        c = (f21 - f11) / dl - d * ep
        a1 = (f12 - f11) / ep - l1 * d
        a0 = f11 - a1 * ep - l1 * c - l1 * d * ep
        return max(0.0, a0 + a1 * e_full + l_full * (c + d * e_full))

    out = {k: fit(lambda x, _k=k: x[_k])
           for k in ("flops", "transcendentals", "bytes_accessed")}
    kinds = set()
    for mm_ in m.values():
        kinds |= set(mm_["collectives"])
    colls = {}
    for k in kinds:
        def g_bytes(x, _k=k):
            return x["collectives"].get(_k, {}).get("operand_bytes", 0)

        def g_count(x, _k=k):
            return x["collectives"].get(_k, {}).get("count", 0)

        colls[k] = dict(count=int(round(fit(g_count))),
                        operand_bytes=int(fit(g_bytes)))
    out["collectives"] = colls
    return out


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             out_dir: str, with_probes: bool = True) -> dict:
    import jax
    from repro.configs import get_arch
    from repro.launch.cells import (build_cell, build_probe_cell,
                                    probe_layer_counts)
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    arch = get_arch(arch_name)
    cell = build_cell(arch, shape_name, mesh)

    rec = dict(arch=arch_name, shape=shape_name, mesh=mesh_kind,
               mesh_shape=dict(mesh.shape), meta=cell.meta, ok=False)
    t0 = time.time()
    try:
        with mesh:
            lowered = cell.lower()
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
            ma = compiled.memory_analysis()
            rec["memory"] = dict(
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                alias_bytes=int(ma.alias_size_in_bytes),
                peak_bytes=int(ma.argument_size_in_bytes +
                               ma.output_size_in_bytes +
                               ma.temp_size_in_bytes -
                               ma.alias_size_in_bytes),
            )
            raw = _measure(compiled)
            rec["cost_raw"] = {k: raw[k] for k in
                               ("flops", "transcendentals", "bytes_accessed")}
            rec["collectives_raw"] = raw["collectives"]
            rec["hlo_bytes"] = len(compiled.as_text())

            # scan trip counts are opaque to cost_analysis → probe-extrapolate
            shape_spec = arch.shapes[shape_name]
            probes = probe_layer_counts(arch, shape_spec) \
                if with_probes else None
            if probes is not None:
                l1, l2, l_full = probes
                nc = shape_spec.sizes.get("edge_chunks", 1)
                if arch.family == "gnn" and nc > 1:
                    # 4-point fit over (layers, edges):
                    # f(L,E) = a0 + a1 E + L c + L d E
                    e_full = shape_spec.sizes["n_edges"]
                    ep = e_full // nc
                    m = {}
                    for li, ei in ((l1, ep), (l2, ep), (l1, 2 * ep),
                                   (l2, 2 * ep)):
                        m[(li, ei)] = _measure(
                            build_probe_cell(arch, shape_name, mesh, li,
                                             n_edges=ei).lower().compile())
                    est = _fit_layers_edges(m, l1, l2, ep, l_full, e_full)
                    rec["probe"] = dict(scheme="layers_x_edges", l1=l1,
                                        l2=l2, ep=ep, l_full=l_full,
                                        e_full=e_full)
                else:
                    m1 = _measure(build_probe_cell(arch, shape_name, mesh,
                                                   l1).lower().compile())
                    m2 = _measure(build_probe_cell(arch, shape_name, mesh,
                                                   l2).lower().compile())
                    est = _extrapolate(m1, m2, l1, l2, l_full)
                    rec["probe"] = dict(scheme="layers", l1=l1, l2=l2,
                                        l_full=l_full, m1=m1, m2=m2)
            else:
                est = raw
            rec["cost"] = {k: est[k] for k in
                           ("flops", "transcendentals", "bytes_accessed")}
            rec["collectives"] = est["collectives"]
            rec["ok"] = True
    except Exception as exc:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(exc).__name__}: {exc}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 2)

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch_name}__{shape_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    from repro.configs import ALL_ARCHS, get_arch

    archs = [args.arch] if args.arch else ALL_ARCHS
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_ok = n_fail = 0
    for mesh_kind in meshes:
        for arch_name in archs:
            arch = get_arch(arch_name)
            shapes = ([args.shape] if args.shape
                      else list(arch.runnable_shapes()))
            for shape_name in shapes:
                if shape_name in arch.skip_shapes:
                    print(f"SKIP {arch_name}/{shape_name}: "
                          f"{arch.skip_shapes[shape_name]}")
                    continue
                rec = run_cell(arch_name, shape_name, mesh_kind,
                               os.path.join(args.out, mesh_kind))
                status = "OK " if rec["ok"] else "FAIL"
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
                mem = rec.get("memory", {}).get("peak_bytes", 0) / 2 ** 30
                fl = rec.get("cost", {}).get("flops", 0)
                print(f"{status} [{mesh_kind}] {arch_name}/{shape_name} "
                      f"t={rec['total_s']}s peak={mem:.2f}GiB/dev "
                      f"flops/dev={fl:.3g}"
                      + ("" if rec["ok"] else f" :: {rec['error']}"),
                      flush=True)
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
