"""Batched serving driver: prefill + decode loop with a KV cache.

Smoke-scale on CPU (``--preset smoke``); the full-scale variants are the
``prefill_32k`` / ``decode_32k`` / ``long_500k`` dry-run cells.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer as T


def serve(arch_name: str = "gemma2-9b", batch: int = 4, prompt_len: int = 32,
          decode_steps: int = 32, max_seq: int = 128, seed: int = 0,
          greedy: bool = True):
    arch = get_arch(arch_name)
    assert arch.family == "lm", "serving driver targets the LM archs"
    cfg = arch.make_smoke_cfg()
    rng = jax.random.PRNGKey(seed)
    params = T.init_params(cfg, rng)
    prompts = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab)

    prefill_fn = jax.jit(lambda p, t: T.prefill(cfg, p, t))
    decode_fn = jax.jit(
        lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos))

    t0 = time.time()
    logits, cache = prefill_fn(params, prompts)
    # pad the cache to max_seq
    cache = {k: jnp.zeros(
        (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
        jnp.bfloat16).at[:, :, :prompt_len].set(v)
        for k, v in cache.items()}
    prefill_s = time.time() - t0

    tokens = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [tokens]
    t0 = time.time()
    for i in range(decode_steps - 1):
        logits, cache = decode_fn(params, cache, tokens,
                                  jnp.int32(prompt_len + i))
        tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(tokens)
    decode_s = time.time() - t0
    out = jnp.stack(generated, axis=1)
    return dict(tokens=np.asarray(out), prefill_s=prefill_s,
                decode_s=decode_s,
                decode_tok_s=batch * (decode_steps - 1) / max(decode_s,
                                                              1e-9))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=32)
    args = ap.parse_args()
    r = serve(args.arch, args.batch, args.prompt_len, args.decode_steps)
    print(f"[serve] prefill {r['prefill_s']:.2f}s, "
          f"decode {r['decode_s']:.2f}s "
          f"({r['decode_tok_s']:.1f} tok/s), sample: {r['tokens'][0][:8]}")


if __name__ == "__main__":
    main()
