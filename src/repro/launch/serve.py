"""Discovery serving driver (DESIGN.md §9): JSONL requests in, JSON
responses out, executed by :class:`repro.service.DiscoveryService`
(round-robin scheduler + result cache) against a registry of demo graphs
(``demo-social`` unlabeled, ``demo-citeseer`` vertex-labeled,
``demo-attributed`` vertex + edge labels).  Label-constrained requests
(DESIGN.md §12) add a ``label_predicate``, e.g.::

    {"graph": "demo-attributed", "workload": "iso", "k": 3,
     "q_edges": [[0, 1], [1, 2], [0, 2]], "q_labels": [1, 1, 1],
     "label_predicate": {"vertex_any_of": [1, 2],
                         "q_any_of": [[1, 2], [1, 2], [1, 2]],
                         "edge_any_of": [0]}}

Durable runs (DESIGN.md §15): requests carrying ``checkpoint_every`` /
``checkpoint_dir`` persist their engine state as they run, and a killed
serve process restarts with ``--resume`` to continue every such request
from its newest committed step — the resumed answers are byte-identical
to an uninterrupted run's.  ``--heartbeat PATH`` touches a liveness file
after every flushed batch so an external supervisor can detect a hung or
killed loop (:class:`repro.runtime.fault_tolerance.Heartbeat`) and
trigger exactly that restart.

Observability (DESIGN.md §16): ``--metrics-dump PATH`` turns on the
process-wide metrics registry and rewrites ``PATH`` with a JSON snapshot
(all counters/gauges/histograms plus span-buffer stats) after every
flushed batch — a scrape-friendly sidecar file.  A control line
``{"cmd": "metrics"}`` in the request stream flushes pending requests and
replies inline with the same live snapshot.

Request schema: docs/API.md; per-workload walkthroughs: docs/WORKLOADS.md.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys


def make_demo_registry():
    """Demo graphs the discovery loop serves out of the box."""
    from repro.data.synthetic_graphs import (attributed_graph,
                                             labeled_graph,
                                             planted_clique_graph)
    from repro.service import GraphRegistry

    registry = GraphRegistry()
    registry.register("demo-social",
                      planted_clique_graph(n=200, m=1200, clique_size=7,
                                           seed=7))
    registry.register("demo-citeseer", labeled_graph(120, 500, 4, seed=11))
    # vertex labels AND edge types: the label-predicate demo target
    # (docs/WORKLOADS.md §labeled variants)
    registry.register("demo-attributed",
                      attributed_graph(150, 700, n_labels=5,
                                       n_edge_labels=2, seed=13))
    return registry


def serve_discovery(lines=None, out=None, slice_steps: int = 1,
                    batch_size: int = 8, resume: bool = False,
                    heartbeat: str = None, metrics_dump: str = None,
                    observability=None):
    """Minimal request loop: one JSON request per input line, one JSON
    response per output line (order-preserving).

    Requests are grouped into batches of ``batch_size`` and each batch's
    cache misses run concurrently under the round-robin scheduler; repeats
    within and across batches hit the result cache.  ``resume=True``
    (the ``--resume`` restart path) forces every checkpointed request to
    continue from its newest committed step instead of starting over;
    ``heartbeat`` names a liveness file beaten after every flushed batch;
    ``metrics_dump`` names a JSON file rewritten with the live metrics
    snapshot after every flush (``observability`` overrides the registry
    used — by default one is created whenever ``metrics_dump`` is set).
    """
    from repro.service import (DiscoveryRequest, DiscoveryResponse,
                               DiscoveryService)
    from repro.obs import NOOP, Observability

    obs = observability
    if obs is None:
        obs = Observability() if metrics_dump else NOOP
    svc = DiscoveryService(registry=make_demo_registry(),
                           slice_steps=slice_steps, observability=obs)
    lines = sys.stdin if lines is None else lines
    out = sys.stdout if out is None else out
    hb = None
    if heartbeat:
        from repro.runtime.fault_tolerance import Heartbeat
        hb = Heartbeat(heartbeat)

    batch = []
    flushed = [0]

    def dump_metrics():
        if metrics_dump:
            tmp = metrics_dump + ".tmp"
            with open(tmp, "w") as f:
                json.dump(obs.snapshot(), f, indent=1)
            os.replace(tmp, metrics_dump)  # readers never see a torn file

    def flush():
        if not batch:
            return
        for resp in svc.serve(batch):
            # flush per line so pipe/socket consumers see responses as
            # they are produced, not when the process exits
            print(resp.to_json(), file=out, flush=True)
        batch.clear()
        flushed[0] += 1
        if hb is not None:
            hb.beat(flushed[0])
        dump_metrics()

    for line in lines:
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        d = {}
        try:
            d = json.loads(line)
            if isinstance(d, dict) and "cmd" in d:
                # control request: flush queued work first so the reply
                # reflects every request that preceded it on the stream
                flush()
                if d["cmd"] == "metrics":
                    reply = {"cmd": "metrics", "status": "ok",
                             "enabled": obs.enabled,
                             "snapshot": obs.snapshot()}
                else:
                    reply = {"cmd": d["cmd"], "status": "error",
                             "error": f"unknown cmd: {d['cmd']!r}"}
                print(json.dumps(reply), file=out, flush=True)
                continue
            req = DiscoveryRequest.from_dict(d)
            if resume and req.checkpoint_dir:
                req = dataclasses.replace(req, resume=True)
        except (ValueError, TypeError) as e:
            flush()   # keep responses in request order
            d = d if isinstance(d, dict) else {}
            print(DiscoveryResponse(
                request_id=d.get("request_id"),
                workload=str(d.get("workload", "unknown")),
                status="error", error=str(e)).to_json(),
                file=out, flush=True)
            continue
        batch.append(req)
        if len(batch) >= batch_size:
            flush()
    flush()
    dump_metrics()   # final snapshot even when the tail batch was empty
    return svc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", default=None,
                    help="JSONL request file (default stdin)")
    ap.add_argument("--slice-steps", type=int, default=1)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--resume", action="store_true",
                    help="continue checkpointed requests from their newest "
                         "committed step (the restart half of a "
                         "kill-and-resume cycle; DESIGN.md §15)")
    ap.add_argument("--heartbeat", default=None, metavar="PATH",
                    help="liveness file beaten after every flushed batch")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="enable the metrics registry and rewrite PATH "
                         "with a JSON snapshot after every flushed batch "
                         "(DESIGN.md §16)")
    args = ap.parse_args()
    lines = open(args.requests) if args.requests else None
    try:
        svc = serve_discovery(lines=lines, slice_steps=args.slice_steps,
                              batch_size=args.batch_size,
                              resume=args.resume, heartbeat=args.heartbeat,
                              metrics_dump=args.metrics_dump)
    finally:
        if lines is not None:
            lines.close()
    print(f"[serve] {svc.requests_served} requests, "
          f"{svc.engine_steps_total} engine steps, "
          f"cache {svc.cache.stats()}", file=sys.stderr)


if __name__ == "__main__":
    main()
