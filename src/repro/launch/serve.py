"""Serving drivers.

Two modes share this entry point:

* ``--mode lm`` (default) — batched LM serving: prefill + decode loop with
  a KV cache.  Smoke-scale on CPU; the full-scale variants are the
  ``prefill_32k`` / ``decode_32k`` / ``long_500k`` dry-run cells.
* ``--mode discovery`` — the multi-query subgraph-discovery request loop
  (DESIGN.md §9): JSONL requests in, JSON responses out, executed by
  :class:`repro.service.DiscoveryService` (round-robin scheduler + result
  cache) against a registry of demo graphs (``demo-social`` unlabeled,
  ``demo-citeseer`` vertex-labeled, ``demo-attributed`` vertex + edge
  labels).  Label-constrained requests (DESIGN.md §12) add a
  ``label_predicate``, e.g.::

      {"graph": "demo-attributed", "workload": "iso", "k": 3,
       "q_edges": [[0, 1], [1, 2], [0, 2]], "q_labels": [1, 1, 1],
       "label_predicate": {"vertex_any_of": [1, 2],
                           "q_any_of": [[1, 2], [1, 2], [1, 2]],
                           "edge_any_of": [0]}}

  Request schema: docs/API.md; per-workload walkthroughs:
  docs/WORKLOADS.md.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer as T


def serve(arch_name: str = "gemma2-9b", batch: int = 4, prompt_len: int = 32,
          decode_steps: int = 32, max_seq: int = 128, seed: int = 0,
          greedy: bool = True):
    arch = get_arch(arch_name)
    assert arch.family == "lm", "serving driver targets the LM archs"
    cfg = arch.make_smoke_cfg()
    rng = jax.random.PRNGKey(seed)
    params = T.init_params(cfg, rng)
    prompts = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab)

    prefill_fn = jax.jit(lambda p, t: T.prefill(cfg, p, t))
    decode_fn = jax.jit(
        lambda p, c, t, pos: T.decode_step(cfg, p, c, t, pos))

    t0 = time.time()
    logits, cache = prefill_fn(params, prompts)
    # pad the cache to max_seq
    cache = {k: jnp.zeros(
        (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
        jnp.bfloat16).at[:, :, :prompt_len].set(v)
        for k, v in cache.items()}
    prefill_s = time.time() - t0

    tokens = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [tokens]
    t0 = time.time()
    for i in range(decode_steps - 1):
        logits, cache = decode_fn(params, cache, tokens,
                                  jnp.int32(prompt_len + i))
        tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(tokens)
    decode_s = time.time() - t0
    out = jnp.stack(generated, axis=1)
    return dict(tokens=np.asarray(out), prefill_s=prefill_s,
                decode_s=decode_s,
                decode_tok_s=batch * (decode_steps - 1) / max(decode_s,
                                                              1e-9))


def make_demo_registry():
    """Demo graphs the discovery loop serves out of the box."""
    from repro.data.synthetic_graphs import (attributed_graph,
                                             labeled_graph,
                                             planted_clique_graph)
    from repro.service import GraphRegistry

    registry = GraphRegistry()
    registry.register("demo-social",
                      planted_clique_graph(n=200, m=1200, clique_size=7,
                                           seed=7))
    registry.register("demo-citeseer", labeled_graph(120, 500, 4, seed=11))
    # vertex labels AND edge types: the label-predicate demo target
    # (docs/WORKLOADS.md §labeled variants)
    registry.register("demo-attributed",
                      attributed_graph(150, 700, n_labels=5,
                                       n_edge_labels=2, seed=13))
    return registry


def serve_discovery(lines=None, out=None, slice_steps: int = 1,
                    batch_size: int = 8):
    """Minimal request loop: one JSON request per input line, one JSON
    response per output line (order-preserving).

    Requests are grouped into batches of ``batch_size`` and each batch's
    cache misses run concurrently under the round-robin scheduler; repeats
    within and across batches hit the result cache.
    """
    from repro.service import (DiscoveryRequest, DiscoveryResponse,
                               DiscoveryService)

    svc = DiscoveryService(registry=make_demo_registry(),
                           slice_steps=slice_steps)
    lines = sys.stdin if lines is None else lines
    out = sys.stdout if out is None else out

    batch = []

    def flush():
        if not batch:
            return
        for resp in svc.serve(batch):
            # flush per line so pipe/socket consumers see responses as
            # they are produced, not when the process exits
            print(resp.to_json(), file=out, flush=True)
        batch.clear()

    for line in lines:
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        d = {}
        try:
            d = json.loads(line)
            req = DiscoveryRequest.from_dict(d)
        except (ValueError, TypeError) as e:
            flush()   # keep responses in request order
            d = d if isinstance(d, dict) else {}
            print(DiscoveryResponse(
                request_id=d.get("request_id"),
                workload=str(d.get("workload", "unknown")),
                status="error", error=str(e)).to_json(),
                file=out, flush=True)
            continue
        batch.append(req)
        if len(batch) >= batch_size:
            flush()
    flush()
    return svc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "discovery"], default="lm")
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--requests", default=None,
                    help="discovery mode: JSONL request file (default stdin)")
    ap.add_argument("--slice-steps", type=int, default=1)
    args = ap.parse_args()
    if args.mode == "discovery":
        lines = open(args.requests) if args.requests else None
        try:
            svc = serve_discovery(lines=lines, slice_steps=args.slice_steps)
        finally:
            if lines is not None:
                lines.close()
        print(f"[serve] {svc.requests_served} requests, "
              f"{svc.engine_steps_total} engine steps, "
              f"cache {svc.cache.stats()}", file=sys.stderr)
        return
    r = serve(args.arch, args.batch, args.prompt_len, args.decode_steps)
    print(f"[serve] prefill {r['prefill_s']:.2f}s, "
          f"decode {r['decode_s']:.2f}s "
          f"({r['decode_tok_s']:.1f} tok/s), sample: {r['tokens'][0][:8]}")


if __name__ == "__main__":
    main()
