"""Cell builders: one jit-able program per (architecture × input shape).

A *cell* bundles the step function, abstract argument shapes
(ShapeDtypeStruct — never allocated), and input shardings for a given mesh.
``launch/dryrun.py`` lowers and compiles these; ``launch/train.py`` runs
the reduced versions with real arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import Arch, ShapeSpec
from repro.models.sharding import LM_RULES, resolve
from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state,
                               opt_state_shapes)

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class CellProgram:
    name: str
    fn: Callable
    args: Tuple[Any, ...]            # pytrees of ShapeDtypeStruct
    in_shardings: Tuple[Any, ...]    # matching pytrees of NamedSharding
    donate: Tuple[int, ...] = ()
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def jit(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       donate_argnums=self.donate)

    def lower(self):
        return self.jit().lower(*self.args)


def _ns(mesh: Mesh, tree_of_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def _mesh_div(mesh: Mesh, want: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in want if a in mesh.shape]))


def _axes_for(mesh: Mesh, want: Tuple[str, ...], dim: int):
    """Largest prefix of ``want`` (axes present in mesh) that divides dim."""
    axes = tuple(a for a in want if a in mesh.shape)
    while axes and dim % int(np.prod([mesh.shape[a] for a in axes])) != 0:
        axes = axes[:-1]
    return axes or None


def _spec0(mesh, want, shape):
    return P(_axes_for(mesh, want, shape[0]),
             *([None] * (len(shape) - 1)))


# ======================================================================
# LM cells
# ======================================================================
def build_lm_cell(arch: Arch, shape: ShapeSpec, mesh: Mesh) -> CellProgram:
    from repro.models import transformer as T
    cfg = arch.make_model_cfg(shape)
    rules = dict(LM_RULES)
    if arch.name == "arctic-480b":
        from repro.configs.arctic_480b import SHARDING_OVERRIDES
        rules.update(SHARDING_OVERRIDES)
    if cfg.moe is not None:
        s_ = shape.sizes
        if shape.kind in ("train", "prefill"):
            nmb_ = s_.get("grad_microbatches", 8) if shape.kind == "train" \
                else 1
            t_call = (s_["global_batch"] * s_["seq_len"] // nmb_ //
                      cfg.moe.token_chunks)
        else:
            t_call = s_["global_batch"]
        from repro.models.moe import capacity as _cap
        cap = _cap(t_call, cfg.moe)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe,
            experts_shard=_axes_for(mesh, ("model",), cfg.moe.num_experts),
            capacity_shard=_axes_for(mesh, ("pod", "data"), cap)))

    pshapes = T.param_shapes(cfg)
    pspecs = T.param_specs(cfg, mesh, rules)
    p_sh = _ns(mesh, pspecs)
    s = shape.sizes
    b = s["global_batch"]

    if shape.kind == "train":
        seq = s["seq_len"]
        oshapes = opt_state_shapes(pshapes)
        ospecs = {"mu": pspecs, "nu": jax.tree.map(lambda x: x, pspecs),
                  "step": P()}
        o_sh = _ns(mesh, ospecs)
        bshapes = {"tokens": SDS((b, seq), jnp.int32),
                   "targets": SDS((b, seq), jnp.int32)}
        bspec = P(_axes_for(mesh, ("pod", "data"), b), None)
        b_sh = {k: NamedSharding(mesh, bspec) for k in bshapes}
        opt_cfg = AdamWConfig()
        # gradient accumulation: activations scale 1/nmb (42 saved layer
        # residuals dominated gemma2's 66 GiB/dev), grads use one buffer
        nmb = s.get("grad_microbatches", 8)
        if b % nmb or (b // nmb) % _mesh_div(mesh, ("pod", "data")):
            nmb = 1

        def grad_fn(params, toks, tgts):
            return jax.value_and_grad(
                lambda p: T.lm_loss(cfg, p, toks, tgts))(params)

        def fn(params, opt, batch):
            if nmb == 1:
                loss, grads = grad_fn(params, batch["tokens"],
                                      batch["targets"])
            else:
                # microbatch split keeps the SHARDED batch dim leading
                # ([mb, nmb, S], slice dim 1) — reshaping to [nmb, mb, S]
                # puts a non-divisible dim on the data axis and GSPMD
                # silently replicates the whole batch (measured: no
                # memory win at all).
                mb = b // nmb
                toks = batch["tokens"].reshape(mb, nmb, seq)
                tgts = batch["targets"].reshape(mb, nmb, seq)

                def body(i, acc):
                    tk = jax.lax.dynamic_slice_in_dim(toks, i, 1, 1)[:, 0]
                    tg = jax.lax.dynamic_slice_in_dim(tgts, i, 1, 1)[:, 0]
                    l, g = grad_fn(params, tk, tg)
                    return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g))

                zero = (jnp.zeros((), jnp.float32),
                        jax.tree.map(lambda p: jnp.zeros(p.shape,
                                                         jnp.float32),
                                     params))
                loss_sum, grads = jax.lax.fori_loop(0, nmb, body, zero)
                loss = loss_sum / nmb
                grads = jax.tree.map(lambda g: g / nmb, grads)
            params, opt, metrics = adamw_update(opt_cfg, params, grads, opt)
            metrics["loss"] = loss
            return params, opt, metrics

        return CellProgram(
            name=f"{arch.name}__{shape.name}", fn=fn,
            args=(pshapes, oshapes, bshapes),
            in_shardings=(p_sh, o_sh, b_sh), donate=(0, 1),
            meta=dict(kind="train", tokens=b * seq,
                      params=cfg.param_count(),
                      active_params=cfg.active_param_count()))

    if shape.kind == "prefill":
        seq = s["seq_len"]
        tshape = SDS((b, seq), jnp.int32)
        tspec = NamedSharding(
            mesh, P(_axes_for(mesh, ("pod", "data"), b), None))

        def fn(params, tokens):
            return T.prefill(cfg, params, tokens)

        return CellProgram(
            name=f"{arch.name}__{shape.name}", fn=fn,
            args=(pshapes, tshape), in_shardings=(p_sh, tspec),
            meta=dict(kind="prefill", tokens=b * seq,
                      params=cfg.param_count(),
                      active_params=cfg.active_param_count()))

    # decode.  The cache shards over (batch, head_dim) — NEVER the sequence
    # axis: a traced-position dynamic-update-slice on a sharded dim makes
    # GSPMD all-gather the whole cache (measured 165 GiB/dev on arctic).
    # head_dim is 16-divisible for every assigned arch; attention contracts
    # it, costing one small score all-reduce per layer instead.
    seq = s["seq_len"]
    cache_shapes = T.make_cache_shapes(cfg, b, seq)
    if b == 1:      # long_500k: every axis onto head_dim
        cspec = P(None, None, None, None,
                  _axes_for(mesh, ("pod", "data", "model"), cfg.head_dim))
    else:
        cspec = P(None, _axes_for(mesh, ("pod", "data"), b), None, None,
                  _axes_for(mesh, ("model",), cfg.head_dim))
    c_sh = {k: NamedSharding(mesh, cspec) for k in cache_shapes}
    tshape = SDS((b,), jnp.int32)
    tspec = NamedSharding(mesh, P(_axes_for(mesh, ("pod", "data"), b)))
    posshape = SDS((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())

    def fn(params, cache, tokens, position):
        return T.decode_step(cfg, params, cache, tokens, position)

    return CellProgram(
        name=f"{arch.name}__{shape.name}", fn=fn,
        args=(pshapes, cache_shapes, tshape, posshape),
        in_shardings=(p_sh, c_sh, tspec, pos_sh), donate=(1,),
        meta=dict(kind="decode", tokens=b,
                  params=cfg.param_count(),
                  active_params=cfg.active_param_count(),
                  kv_len=seq))


# ======================================================================
# GNN cells
# ======================================================================
_GNN_FNS = {}


def _gnn_model(arch_name: str):
    if not _GNN_FNS:
        from repro.models import equivariant as E, gnn as G
        _GNN_FNS.update({
            "schnet": (G.schnet_param_shapes, G.schnet_forward),
            "graphcast": (G.graphcast_param_shapes, G.graphcast_forward),
            "mace": (E.mace_param_shapes, E.mace_forward),
            "equiformer-v2": (E.equiformer_param_shapes,
                              E.equiformer_forward),
        })
    return _GNN_FNS[arch_name]


def build_gnn_cell(arch: Arch, shape: ShapeSpec, mesh: Mesh) -> CellProgram:
    from repro.models.gnn import gnn_loss
    s = shape.sizes
    n, e = s["n_nodes"], s["n_edges"]
    # nodes over (pod, data); hidden channels over model — node-axis
    # sharding over 'model' too made every edge-chunk gather all-gather the
    # full feature tensor (equiformer/ogb: 3.7e3 s collective term)
    node_axes = _axes_for(mesh, ("pod", "data"), n)
    edge_axes = _axes_for(mesh, ("pod", "data", "model"),
                          e // s["edge_chunks"])
    cfg0 = arch.make_model_cfg(shape)
    # channels stay UNSHARDED (E6 refuted: channel-sharded node tensors vs
    # edge-sharded message tensors re-trigger GSPMD involuntary full
    # rematerialization, collective term 223 s -> 1670 s); per-edge tensors
    # are edge-sharded via the pre-chunked [nc, chunk] inputs
    cfg = dataclasses.replace(cfg0, node_shard=node_axes, feat_shard=None)

    shapes_fn, forward = _gnn_model(arch.name)
    pshapes = shapes_fn(cfg)
    pspecs = jax.tree.map(lambda x: P(), pshapes)    # GNN weights replicated
    p_sh = _ns(mesh, pspecs)
    oshapes = opt_state_shapes(pshapes)
    o_sh = _ns(mesh, {"mu": pspecs, "nu": jax.tree.map(lambda x: x, pspecs),
                      "step": P()})

    node_sp = P(node_axes, None)
    nc_ = s["edge_chunks"]
    edge_sp = P(None, edge_axes)       # pre-chunked [nc, chunk]
    bshapes = {
        "features": SDS((n, s["d_feat"]), jnp.float32),
        "positions": SDS((n, 3), jnp.float32),
        "edge_src": SDS((nc_, e // nc_), jnp.int32),
        "edge_dst": SDS((nc_, e // nc_), jnp.int32),
    }
    bspecs = {
        "features": node_sp, "positions": node_sp,
        "edge_src": edge_sp, "edge_dst": edge_sp,
    }
    static = {}
    if s.get("batch_graphs"):
        g = s["batch_graphs"]
        bshapes["graph_ids"] = SDS((n,), jnp.int32)
        bspecs["graph_ids"] = P(node_axes)
        bshapes["targets"] = SDS((g, s["d_out"]), jnp.float32)
        bspecs["targets"] = P(None, None)
        static["num_graphs"] = g
    else:
        bshapes["targets"] = SDS((n, s["d_out"]), jnp.float32)
        bspecs["targets"] = node_sp
        if s.get("sampled"):
            bshapes["node_mask"] = SDS((n,), jnp.float32)
            bspecs["node_mask"] = P(node_axes)
    b_sh = _ns(mesh, bspecs)
    opt_cfg = AdamWConfig()

    def fn(params, opt, batch):
        full = {**batch, **static}
        loss, grads = jax.value_and_grad(
            lambda p: gnn_loss(forward, cfg, p, full))(params)
        params, opt, metrics = adamw_update(opt_cfg, params, grads, opt)
        metrics["loss"] = loss
        return params, opt, metrics

    nparams = int(sum(np.prod(x.shape) for x in jax.tree.leaves(pshapes)))
    return CellProgram(
        name=f"{arch.name}__{shape.name}", fn=fn,
        args=(pshapes, oshapes, bshapes),
        in_shardings=(p_sh, o_sh, b_sh), donate=(0, 1),
        meta=dict(kind="train", nodes=n, edges=e, params=nparams,
                  active_params=nparams))


# ======================================================================
# recsys cells
# ======================================================================
def build_recsys_cell(arch: Arch, shape: ShapeSpec, mesh: Mesh) -> CellProgram:
    from repro.models import recsys as R
    cfg = arch.make_model_cfg(shape)
    pshapes = R.widedeep_param_shapes(cfg)
    pspecs = R.widedeep_param_specs(cfg, mesh)
    p_sh = _ns(mesh, pspecs)
    s = shape.sizes
    nparams = int(sum(np.prod(x.shape) for x in jax.tree.leaves(pshapes)))
    # embedding tables are gathered (O(F·D) per example), not matmul'd:
    # MODEL_FLOPS counts the dense MLP + per-example embedding rows
    mlp_params = int(sum(np.prod(v.shape) for k, v in pshapes.items()
                         if k.startswith("mlp"))) + \
        cfg.n_sparse * cfg.embed_dim

    if shape.kind == "train":
        b = s["batch"]
        oshapes = opt_state_shapes(pshapes)
        o_sh = _ns(mesh, {"mu": pspecs,
                          "nu": jax.tree.map(lambda x: x, pspecs),
                          "step": P()})
        baxes = _axes_for(mesh, ("pod", "data"), b)
        bshapes = {"sparse_ids": SDS((b, cfg.n_sparse), jnp.int32),
                   "dense": SDS((b, cfg.n_dense), jnp.float32),
                   "labels": SDS((b,), jnp.float32)}
        b_sh = _ns(mesh, {"sparse_ids": P(baxes, None),
                          "dense": P(baxes, None), "labels": P(baxes)})
        opt_cfg = AdamWConfig()

        def fn(params, opt, batch):
            loss, grads = jax.value_and_grad(
                lambda p: R.widedeep_loss(cfg, p, batch))(params)
            params, opt, metrics = adamw_update(opt_cfg, params, grads, opt)
            metrics["loss"] = loss
            return params, opt, metrics

        return CellProgram(
            name=f"{arch.name}__{shape.name}", fn=fn,
            args=(pshapes, oshapes, bshapes),
            in_shardings=(p_sh, o_sh, b_sh), donate=(0, 1),
            meta=dict(kind="train", batch=b, params=nparams,
                      active_params=mlp_params))

    if shape.kind == "serve":
        b = s["batch"]
        baxes = _axes_for(mesh, ("pod", "data", "model") if b >= 4096
                          else ("pod", "data"), b)
        bshapes = {"sparse_ids": SDS((b, cfg.n_sparse), jnp.int32),
                   "dense": SDS((b, cfg.n_dense), jnp.float32)}
        b_sh = _ns(mesh, {"sparse_ids": P(baxes, None),
                          "dense": P(baxes, None)})

        def fn(params, batch):
            return R.widedeep_serve(cfg, params, batch)

        return CellProgram(
            name=f"{arch.name}__{shape.name}", fn=fn,
            args=(pshapes, bshapes), in_shardings=(p_sh, b_sh),
            meta=dict(kind="serve", batch=b, params=nparams,
                      active_params=mlp_params))

    # retrieval
    c = s["n_candidates"]
    caxes = _axes_for(mesh, ("pod", "data", "model"), c)
    dshape = SDS((1, cfg.n_dense), jnp.float32)
    ishape = SDS((1, cfg.n_sparse), jnp.int32)
    cshape = SDS((c,), jnp.int32)

    def fn(params, dense, base_ids, cand_ids):
        return R.widedeep_retrieval_fast(cfg, params, dense, base_ids,
                                         cand_ids)

    return CellProgram(
        name=f"{arch.name}__{shape.name}", fn=fn,
        args=(pshapes, dshape, ishape, cshape),
        in_shardings=(p_sh, NamedSharding(mesh, P(None, None)),
                      NamedSharding(mesh, P(None, None)),
                      NamedSharding(mesh, P(caxes))),
        meta=dict(kind="retrieval", candidates=c, params=nparams,
                  active_params=mlp_params))


def build_cell(arch: Arch, shape_name: str, mesh: Mesh) -> CellProgram:
    shape = arch.shapes[shape_name]
    if arch.family == "lm":
        return build_lm_cell(arch, shape, mesh)
    if arch.family == "gnn":
        return build_gnn_cell(arch, shape, mesh)
    return build_recsys_cell(arch, shape, mesh)


# ======================================================================
# cost probes
# ======================================================================
# XLA's cost_analysis counts a while/scan body ONCE (trip count is opaque),
# so chunked/scanned cells under-report FLOPs.  Probes rebuild the cell with
# n_layers = L' and all chunking disabled (chunking never changes matmul
# totals — online-softmax rescales and capacity rounding are noise), then
# the driver extrapolates affinely:
#     total(L) = f(L1) + (L - L1) * (f(L2) - f(L1)) / (L2 - L1)
# Decode / serve / retrieval cells have no scans (decode unrolls layers in
# Python) → exact without probes.

def probe_layer_counts(arch: Arch, shape: ShapeSpec):
    """(L1, L2, L_full) for the affine probe, or None when exact."""
    if arch.family == "lm":
        if shape.kind == "decode":
            return None
        l_full = arch.make_model_cfg(shape).n_layers
        return (2, 4, l_full)       # pairs keep gemma2's local/global mix
    if arch.family == "gnn":
        cfg = arch.make_model_cfg(shape)
        l_full = getattr(cfg, "n_layers", None) or cfg.n_interactions
        return (1, 2, l_full)
    return None                      # recsys: no scans


def build_probe_cell(arch: Arch, shape_name: str, mesh: Mesh,
                     n_layers: int,
                     n_edges: Optional[int] = None) -> CellProgram:
    """Probe variant: L layers, all chunking disabled (single-trip HLO, so
    cost_analysis is exact); GNN probes may also shrink the edge count for
    the 4-point (layers × edges) fit."""
    shape = arch.shapes[shape_name]
    if arch.family == "lm":
        base_make = arch.make_model_cfg
        shape = dataclasses.replace(
            shape, sizes={**shape.sizes, "grad_microbatches": 1})

        def make_probe(sh):
            cfg = base_make(sh)
            seq = sh.sizes["seq_len"]
            moe = (dataclasses.replace(cfg.moe, token_chunks=1)
                   if cfg.moe else None)
            return dataclasses.replace(
                cfg, n_layers=n_layers, q_chunk=seq, kv_chunk=seq,
                loss_chunk=seq, moe=moe, unroll_layers=True)

        probe_arch = dataclasses.replace(arch, make_model_cfg=make_probe)
        return build_lm_cell(probe_arch, shape, mesh)

    base_make = arch.make_model_cfg
    if n_edges is not None:
        shape = dataclasses.replace(
            shape, sizes={**shape.sizes, "n_edges": n_edges,
                          "edge_chunks": 1})

    def make_probe(sh):
        cfg = base_make(sh)
        field = ("n_interactions" if hasattr(cfg, "n_interactions")
                 else "n_layers")
        return dataclasses.replace(cfg, **{field: n_layers,
                                           "edge_chunks": 1})

    probe_arch = dataclasses.replace(arch, make_model_cfg=make_probe)
    return build_gnn_cell(probe_arch, shape, mesh)
