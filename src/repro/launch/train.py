"""End-to-end training driver with fault tolerance.

Runs any registered architecture at ``--preset smoke`` (reduced config, CPU)
or ``--preset full`` (production shapes — intended for real TPU meshes).
Demonstrates the whole substrate: deterministic data pipeline, AdamW,
async checkpointing with atomic commit, crash/restart recovery, straggler
watch, heartbeats.

Fault-tolerance drill::

    python -m repro.launch.train --arch glm4-9b --steps 40 --fail-at-step 25
    python -m repro.launch.train --arch glm4-9b --steps 40 --resume
    # → resumes from the last committed checkpoint, bitwise-identical stream

(tests/test_substrate.py runs exactly this drill in-process.)
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.data.pipeline import (NeighborSampler, RecsysStream, TokenStream)
from repro.data.synthetic_graphs import densifying_graph
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.runtime.fault_tolerance import Heartbeat, StragglerMonitor


def _init_from_shapes(shapes, rng, scale=0.05):
    leaves, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, [
        jax.random.normal(k, s.shape, s.dtype) * scale
        for k, s in zip(keys, leaves)])


def build_smoke(arch_name: str, batch: int, seq: int, seed: int):
    """(params, loss_fn, batch_fn) for the reduced config of an arch."""
    arch = get_arch(arch_name)
    cfg = arch.make_smoke_cfg()
    rng = jax.random.PRNGKey(seed)

    if arch.family == "lm":
        from repro.models import transformer as T
        params = T.init_params(cfg, rng)
        stream = TokenStream(cfg.vocab, batch, seq, seed=seed)

        def loss_fn(p, b):
            return T.lm_loss(cfg, p, b["tokens"], b["targets"])

        def batch_fn(step):
            b = stream.batch_at(step)
            return {k: jnp.asarray(v) for k, v in b.items()}

        return params, loss_fn, batch_fn

    if arch.family == "gnn":
        from repro.launch.cells import _gnn_model
        from repro.models.gnn import gnn_loss
        shapes_fn, forward = _gnn_model(arch_name)
        params = _init_from_shapes(shapes_fn(cfg), rng)
        g = densifying_graph(400, 1600, seed)
        d_out = getattr(cfg, "d_out", None) or cfg.n_vars   # graphcast: n_vars
        sampler = NeighborSampler(g, batch_nodes=32, fanout=(4, 4),
                                  d_feat=cfg.d_in, d_out=d_out,
                                  seed=seed)

        def loss_fn(p, b):
            return gnn_loss(forward, cfg, p, b)

        def batch_fn(step):
            s = sampler.sample(step)
            return dict(features=jnp.asarray(s.features),
                        positions=jnp.asarray(s.positions),
                        edge_src=jnp.asarray(s.edge_src),
                        edge_dst=jnp.asarray(s.edge_dst),
                        targets=jnp.asarray(s.targets),
                        node_mask=jnp.asarray(s.node_mask))

        return params, loss_fn, batch_fn

    from repro.models import recsys as R
    params = _init_from_shapes(R.widedeep_param_shapes(cfg), rng)
    stream = RecsysStream(cfg.n_sparse, cfg.n_dense, cfg.vocab_per_field,
                          batch, seed=seed)

    def loss_fn(p, b):
        return R.widedeep_loss(cfg, p, b)

    def batch_fn(step):
        b = stream.batch_at(step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return params, loss_fn, batch_fn


def train(arch_name: str, steps: int, batch: int = 8, seq: int = 128,
          seed: int = 0, checkpoint_dir: Optional[str] = None,
          checkpoint_every: int = 10, resume: bool = False,
          fail_at_step: Optional[int] = None, log_every: int = 10,
          opt_cfg: Optional[AdamWConfig] = None):
    params, loss_fn, batch_fn = build_smoke(arch_name, batch, seq, seed)
    opt_cfg = opt_cfg or AdamWConfig(peak_lr=1e-3, warmup_steps=20,
                                     decay_steps=max(steps, 100))
    opt = init_opt_state(params)
    start_step = 0

    mgr = CheckpointManager(checkpoint_dir) if checkpoint_dir else None
    if resume and mgr is not None and mgr.latest_step() is not None:
        state = mgr.restore({"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start_step = mgr.latest_step()
        print(f"[train] resumed from step {start_step}")

    @jax.jit
    def step_fn(params, opt, b):
        loss, grads = jax.value_and_grad(loss_fn)(params, b)
        params, opt, m = adamw_update(opt_cfg, params, grads, opt)
        m["loss"] = loss
        return params, opt, m

    monitor = StragglerMonitor()
    hb = Heartbeat(f"{checkpoint_dir}/heartbeat" if checkpoint_dir
                   else "/tmp/repro_heartbeat")
    losses = []
    for step in range(start_step, steps):
        t0 = time.time()
        params, opt, m = step_fn(params, opt, batch_fn(step))
        loss = float(m["loss"])
        losses.append(loss)
        dt = time.time() - t0
        if monitor.record(step, dt):
            print(f"[train] straggler at step {step}: {dt:.2f}s "
                  f"(ema {monitor.ema:.2f}s)")
        hb.beat(step)
        if log_every and step % log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} {dt * 1e3:.0f}ms")
        if mgr is not None and (step + 1) % checkpoint_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt})
        if fail_at_step is not None and step + 1 == fail_at_step:
            mgr and mgr.wait()
            raise SystemExit(f"[train] simulated failure at step {step + 1}")
    mgr and mgr.wait()
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    args = ap.parse_args()
    _, losses = train(args.arch, args.steps, args.batch, args.seq,
                      args.seed, args.checkpoint_dir, args.checkpoint_every,
                      args.resume, args.fail_at_step)
    print(f"[train] done; first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
