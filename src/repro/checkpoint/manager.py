"""Sharded checkpointing with atomic commit, async writes, and elastic
restore.

Layout (one directory per step)::

    <dir>/step_000120/
        manifest.json        # step, leaf names/shapes/dtypes, mesh shape
        <leaf-name>.npy      # one file per pytree leaf
        COMMITTED            # written last — partial checkpoints are ignored

Writes go to ``step_N.tmp`` and are renamed into place after the commit
marker, so a crash mid-save never corrupts the latest checkpoint (restart
just picks the newest *committed* step).  Saving runs on a background
thread (async checkpointing — training continues while the previous step
flushes); ``wait()`` joins it.

Elastic restore: leaves are stored as full (host-replicated) arrays, so a
checkpoint written on one mesh restores onto any other mesh — the caller
re-shards by passing the new shardings (``restore(..., shardings=...)``).
Production multi-host would write per-shard files via
``jax.experimental.multihost_utils``; the format keeps that door open via
the manifest's ``mesh`` field.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np
import jax


def _leaf_names(tree) -> list:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append("__".join(parts))
    return names


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot to host then write asynchronously."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, host_tree):
        names = _leaf_names(host_tree)
        leaves = jax.tree.leaves(host_tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for name, leaf in zip(names, leaves):
            np.save(os.path.join(tmp, name + ".npy"), leaf)
            manifest["leaves"].append(
                {"name": name, "shape": list(leaf.shape),
                 "dtype": str(leaf.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.committed_steps())
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    # --------------------------------------------------------------- restore
    def committed_steps(self) -> list:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d, "COMMITTED")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; optionally re-shard onto a
        (possibly different — elastic) mesh via ``shardings``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        names = _leaf_names(like)
        leaves = [np.load(os.path.join(path, n + ".npy")) for n in names]
        treedef = jax.tree.structure(like)
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree
