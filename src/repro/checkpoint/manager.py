"""Durable engine checkpoints with atomic commit and async writes
(DESIGN.md §15).

Layout (one directory per step)::

    <dir>/step_000120/
        manifest.json        # step, leaf names/shapes/dtypes, extra payload
        <leaf-name>.npy      # one file per pytree leaf
        vpq/...              # side files written by the capture hook
        COMMITTED            # written last inside the tmp dir

Writes go to ``step_N.tmp`` and are renamed into place only after every
file — leaves, side files, manifest, commit marker — exists, so a crash at
*any* moment never corrupts a restorable step: restart just picks the
newest directory whose ``COMMITTED`` marker exists (``committed_steps()``
skips ``.tmp`` and uncommitted dirs).  The rename is the single commit
point (:meth:`_commit` — factored out so the crash-injection harness can
kill the process between tmp-write and rename and prove exactly that).

Saving is split in two so the engine can keep mutating after ``save()``
returns:

* the **capture hook** runs synchronously on the caller's thread —
  anything that references live, mutable engine structures (the VPQ's
  spill runs, which the engine deletes as they exhaust) must be captured
  *now*, into the tmp dir (``capture(tmp_dir) -> dict``); its return value
  lands in the manifest's ``extra`` field;
* the **leaf writes** (already ``device_get`` host copies) plus manifest
  and commit run on a background thread (async checkpointing — the run
  continues while the previous step flushes); ``wait()`` joins it.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import numpy as np
import jax

from repro.obs import NOOP


def _leaf_names(tree) -> list:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append("__".join(parts))
    return names


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3, obs=None):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        # observability handles (DESIGN.md §16); metrics are thread-safe,
        # so the writer thread records into the same registry
        self.obs = obs if obs is not None else NOOP
        self._m_saves = self.obs.counter(
            "checkpoint_saves_total", "checkpoint save() calls")
        self._m_bytes = self.obs.counter(
            "checkpoint_bytes_written_total",
            "bytes committed (leaves + side files + manifest)")
        self._h_capture = self.obs.histogram(
            "checkpoint_capture_seconds",
            "synchronous capture-hook duration (blocks the engine)")
        self._h_commit = self.obs.histogram(
            "checkpoint_commit_seconds",
            "writer-thread flush+commit duration (off the engine path)")
        # a crash between tmp-write and rename strands a ``.tmp`` dir;
        # it is uncommitted garbage by definition (the rename is the
        # commit point), so sweep it on attach
        for d in os.listdir(directory):
            if d.startswith("step_") and d.endswith(".tmp"):
                shutil.rmtree(os.path.join(directory, d),
                              ignore_errors=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, blocking: bool = False,
             capture: Optional[Callable[[str], Dict[str, Any]]] = None):
        """Snapshot ``tree`` to host, run ``capture`` synchronously into the
        tmp dir, then write and commit asynchronously."""
        with self.obs.span("checkpoint.save"):
            self._m_saves.inc()
            host_tree = jax.tree.map(
                lambda x: np.asarray(jax.device_get(x)), tree)
            self.wait()
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            # synchronous: side files must reference engine structures
            # *before* the caller mutates them again (e.g. VPQ runs
            # deleted on exhaust)
            t0 = time.perf_counter() if self.obs.enabled else 0.0
            with self.obs.span("checkpoint.capture"):
                extra = capture(tmp) if capture is not None else None
            if self.obs.enabled:
                self._h_capture.observe(time.perf_counter() - t0)
            self._thread = threading.Thread(
                target=self._write,
                args=(step, host_tree, tmp, final, extra), daemon=True)
            self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, host_tree, tmp: str, final: str, extra):
        t0 = time.perf_counter() if self.obs.enabled else 0.0
        with self.obs.span("checkpoint.commit"):
            names = _leaf_names(host_tree)
            leaves = jax.tree.leaves(host_tree)
            manifest = {"step": step, "leaves": [], "extra": extra}
            for name, leaf in zip(names, leaves):
                np.save(os.path.join(tmp, name + ".npy"), leaf)
                manifest["leaves"].append(
                    {"name": name, "shape": list(leaf.shape),
                     "dtype": str(leaf.dtype)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "COMMITTED"), "w") as f:
                f.write("ok")
            if self.obs.enabled:
                self._m_bytes.inc(sum(
                    os.path.getsize(os.path.join(root, f))
                    for root, _dirs, files in os.walk(tmp) for f in files))
            self._commit(tmp, final)
            self._gc()
        if self.obs.enabled:
            self._h_commit.observe(time.perf_counter() - t0)

    def _commit(self, tmp: str, final: str):
        """The atomic commit point: everything before this is invisible to
        ``committed_steps()``; after the rename the step is durable."""
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)

    def _gc(self):
        steps = sorted(self.committed_steps())
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    # --------------------------------------------------------------- restore
    def committed_steps(self) -> list:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp") and \
                    os.path.exists(os.path.join(self.dir, d, "COMMITTED")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def path(self, step: int) -> str:
        """Directory of a committed step (the capture hook's side files
        live under it)."""
        return os.path.join(self.dir, f"step_{step:08d}")

    def read_manifest(self, step: Optional[int] = None) -> Dict[str, Any]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        with open(os.path.join(self.path(step), "manifest.json")) as f:
            return json.load(f)

    def restore(self, like: Any, step: Optional[int] = None) -> Any:
        """Restore the leaf arrays into the structure of ``like``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = self.path(step)
        names = _leaf_names(like)
        leaves = [np.load(os.path.join(path, n + ".npy")) for n in names]
        treedef = jax.tree.structure(like)
        return jax.tree.unflatten(treedef, leaves)
