"""Pallas TPU kernel: batched clique-frontier expansion (the paper's hot
loop, DESIGN.md §2.1).

For B dequeued cliques with candidate bitsets ``P [B, W]`` (uint32 words)
and the precomputed per-vertex extension masks ``ext = N(v) ∩ {u > v}``
packed as ``[N, W]``, computes ``counts[b, v] = popcount(P[b] & ext[v])`` —
the |P| of every possible child clique, feeding priority and the CP bound.

Since the masked-intersection generalization (DESIGN.md §10) this is the
mask-free specialization of :mod:`repro.kernels.masked_intersect`, kept as
a named entry point because it *is* the paper's clique kernel; the tiling
argument ([bB, W] × [bN, W] VMEM working set instead of the reference's
full [B, N, W] intersection) lives there and in docs/KERNELS.md.

``interpret=None`` auto-detects the backend: real lowering on TPU,
interpreter mode elsewhere (the old hardcoded ``interpret=True`` silently
ran the interpreter on TPU).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .masked_intersect import (DEFAULT_BLOCK_B, DEFAULT_BLOCK_N,
                               masked_intersect)

__all__ = ["frontier_expand", "DEFAULT_BLOCK_B", "DEFAULT_BLOCK_N"]


def frontier_expand(p_bits: jnp.ndarray, ext_bits: jnp.ndarray,
                    block_b: int = DEFAULT_BLOCK_B,
                    block_n: int = DEFAULT_BLOCK_N,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """counts[b, v] = popcount(p_bits[b] & ext_bits[v]); int32 [B, N]."""
    return masked_intersect(p_bits, ext_bits, None,
                            block_b=block_b, block_n=block_n,
                            interpret=interpret)
