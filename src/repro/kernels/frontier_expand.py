"""Pallas TPU kernel: batched clique-frontier expansion (the paper's hot
loop, DESIGN.md §2).

For B dequeued cliques with candidate bitsets ``P [B, W]`` (uint32 words)
and the precomputed per-vertex extension masks ``ext = N(v) ∩ {u > v}``
packed as ``[N, W]``, computes ``counts[b, v] = popcount(P[b] & ext[v])`` —
the |P| of every possible child clique, feeding priority and the CP bound.

TPU mapping: this is a bitwise-AND/popcount "matmul" over the word axis —
pure VPU work.  The grid tiles (B, N); each step holds a ``[bB, W]`` P tile
and a ``[bN, W]`` ext tile in VMEM and materializes only the
``[bB, bN, W]`` intersection tile (vs. the full ``[B, N, W]`` the jnp
reference allocates — the VMEM working-set win that makes expansion
HBM-bandwidth bound instead of capacity bound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_B = 8
DEFAULT_BLOCK_N = 128


def _kernel(p_ref, ext_ref, out_ref):
    p = p_ref[...]                       # [bB, W] uint32
    ext = ext_ref[...]                   # [bN, W] uint32
    inter = p[:, None, :] & ext[None, :, :]
    counts = jnp.sum(jax.lax.population_count(inter).astype(jnp.int32),
                     axis=-1)
    out_ref[...] = counts                # [bB, bN]


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_n", "interpret"))
def frontier_expand(p_bits: jnp.ndarray, ext_bits: jnp.ndarray,
                    block_b: int = DEFAULT_BLOCK_B,
                    block_n: int = DEFAULT_BLOCK_N,
                    interpret: bool = True) -> jnp.ndarray:
    """counts[b, v] = popcount(p_bits[b] & ext_bits[v]); int32 [B, N]."""
    b, w = p_bits.shape
    n, w2 = ext_bits.shape
    assert w == w2
    bb = min(block_b, b)
    bn = min(block_n, n)
    pad_b = (-b) % bb
    pad_n = (-n) % bn
    if pad_b:
        p_bits = jnp.pad(p_bits, ((0, pad_b), (0, 0)))
    if pad_n:
        ext_bits = jnp.pad(ext_bits, ((0, pad_n), (0, 0)))
    bp, np_ = b + pad_b, n + pad_n

    out = pl.pallas_call(
        _kernel,
        grid=(bp // bb, np_ // bn),
        in_specs=[
            pl.BlockSpec((bb, w), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.int32),
        interpret=interpret,
    )(p_bits, ext_bits)
    return out[:b, :n]
