"""Pallas TPU kernel: causal flash attention (LM training/prefill hot spot).

Online-softmax over KV tiles with running (max, denom, acc) carried in VMEM
scratch; the [S, S] score matrix never exists.  Grid (head, q-tile,
kv-tile) with kv innermost so the scratch carries across the reduction
axis; causal tiles above the diagonal contribute nothing (masked; a
production refinement skips them via grid remapping — noted in
EXPERIMENTS.md §Perf).

q/k/v are [H, S, D] (the ops wrapper folds batch and GQA groups into H);
MXU-aligned tiles: q-tile 128×D, kv-tile 128×D.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
            block_q, block_k, scale, causal):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # [bQ, D]
    k = k_ref[0]                                   # [bK, D]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qp = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kp = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(qp >= kp, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)[:, None]
                      ).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jnp.ndarray:
    """q/k/v: [H, S, D] → [H, S, D] fp32."""
    h, s, d = q.shape
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    scale = 1.0 / (d ** 0.5)

    return pl.pallas_call(
        functools.partial(_kernel, block_q=bq, block_k=bk, scale=scale,
                          causal=causal),
        grid=(h, s // bq, s // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hi, qi, ki: (hi, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda hi, qi, ki: (hi, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda hi, qi, ki: (hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda hi, qi, ki: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denom
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
