"""Pallas TPU kernel: batched masked popcount-intersection over packed
bitsets — the one kernel behind every discovery workload's hot set check
(docs/KERNELS.md, DESIGN.md §10).

Contract (``W`` = uint32 words per bitset, ``a/mask [B, W]``, ``b [N, W]``)::

    counts[r, c] = popcount(a[r] & mask[r] & b[c])        # int32 [B, N]

``mask`` is the per-row constraint bitset (``None`` = all-ones).  The same
product serves every workload's call shape (the full operand table lives
in docs/KERNELS.md — label-constrained variants reuse these shapes with
predicate bitsets folded into the operands, DESIGN.md §12):

* **cross counts** (clique): ``a = P`` candidate bitsets, ``b = ext`` masks,
  no row mask — ``counts`` is the |P| of every child clique
  (:func:`frontier_expand` is exactly this specialization);
* **membership / candidate-set materialization** (iso): ``a`` = label
  bitset of the next query vertex, ``mask`` = the state's
  adjacency/complement constraint product, ``b = bitset.eye_table(n)``
  (one-hot rows) — ``counts[r, v] ∈ {0, 1}`` materializes the candidate
  grid for a whole dequeued batch in one call;
* **pair probes** (pattern mining): ``a = adj[u]``, ``mask = eye[v]``,
  ``b = ones [1, W]`` — ``counts[e, 0]`` is the edge-existence bit for
  every embedding in the batch.

TPU mapping: bitwise-AND/popcount "matmul" over the word axis — pure VPU
work.  The grid tiles (B, N); each step holds a ``[bB, W]`` row tile
(plus its mask tile) and a ``[bN, W]`` column tile in VMEM and
materializes only the ``[bB, bN, W]`` intersection tile, vs. the full
``[B, N, W]`` the jnp reference allocates — the VMEM working-set win that
makes expansion HBM-bandwidth bound instead of capacity bound.

Ragged shapes are handled by zero-padding B and N up to the block grid
(zero rows/columns contribute zero counts and are sliced off), so any
(B, N, W) — including W=1 and non-multiple-of-block sizes — is legal.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret

DEFAULT_BLOCK_B = 8
DEFAULT_BLOCK_N = 128


def _kernel(a_ref, b_ref, out_ref):
    a = a_ref[...]                       # [bB, W] uint32
    b = b_ref[...]                       # [bN, W] uint32
    inter = a[:, None, :] & b[None, :, :]
    out_ref[...] = jnp.sum(
        jax.lax.population_count(inter).astype(jnp.int32), axis=-1)


def _kernel_masked(a_ref, mask_ref, b_ref, out_ref):
    a = a_ref[...] & mask_ref[...]       # [bB, W] uint32
    b = b_ref[...]                       # [bN, W] uint32
    inter = a[:, None, :] & b[None, :, :]
    out_ref[...] = jnp.sum(
        jax.lax.population_count(inter).astype(jnp.int32), axis=-1)


@functools.partial(jax.jit, static_argnames=("block_b", "block_n",
                                             "interpret"))
def _masked_intersect(a_bits, b_bits, mask_bits,
                      block_b: int, block_n: int, interpret: bool):
    b, w = a_bits.shape
    n, w2 = b_bits.shape
    assert w == w2, f"word-width mismatch: rows W={w}, columns W={w2}"
    bb = min(block_b, b)
    bn = min(block_n, n)
    pad_b = (-b) % bb
    pad_n = (-n) % bn
    if pad_b:
        a_bits = jnp.pad(a_bits, ((0, pad_b), (0, 0)))
        if mask_bits is not None:
            mask_bits = jnp.pad(mask_bits, ((0, pad_b), (0, 0)))
    if pad_n:
        b_bits = jnp.pad(b_bits, ((0, pad_n), (0, 0)))
    bp, np_ = b + pad_b, n + pad_n

    row_spec = pl.BlockSpec((bb, w), lambda i, j: (i, 0))
    col_spec = pl.BlockSpec((bn, w), lambda i, j: (j, 0))
    if mask_bits is None:
        kernel, in_specs, operands = \
            _kernel, [row_spec, col_spec], (a_bits, b_bits)
    else:
        kernel, in_specs, operands = (_kernel_masked,
                                      [row_spec, row_spec, col_spec],
                                      (a_bits, mask_bits, b_bits))
    out = pl.pallas_call(
        kernel,
        grid=(bp // bb, np_ // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.int32),
        interpret=interpret,
    )(*operands)
    return out[:b, :n]


def masked_intersect(a_bits: jnp.ndarray, b_bits: jnp.ndarray,
                     mask_bits: Optional[jnp.ndarray] = None,
                     block_b: int = DEFAULT_BLOCK_B,
                     block_n: int = DEFAULT_BLOCK_N,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """``counts[r, c] = popcount(a[r] & mask[r] & b[c])``; int32 [B, N].

    ``mask_bits=None`` means no row mask; ``interpret=None`` auto-detects
    the backend (:func:`repro.kernels.runtime.default_interpret`).
    """
    if mask_bits is not None:
        assert mask_bits.shape == a_bits.shape, \
            f"mask shape {mask_bits.shape} != rows shape {a_bits.shape}"
    return _masked_intersect(a_bits, b_bits, mask_bits,
                             block_b=block_b, block_n=block_n,
                             interpret=resolve_interpret(interpret))
