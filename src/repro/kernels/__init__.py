# Pallas TPU kernels for the discovery engine and co-workloads.
#
# Layout (docs/KERNELS.md, DESIGN.md §10): <name>.py holds one kernel,
# ref.py holds its pure-jnp oracle (<name>_ref, identical semantics),
# ops.py is the public wrapper layer with backend auto-detection
# (runtime.py), and tests/test_kernels.py sweeps shapes against the
# oracles in interpret mode.  Add kernels ONLY for compute hot-spots the
# paper itself optimizes; the discovery hot loop is masked_intersect.py
# (frontier_expand.py is its mask-free clique specialization).
