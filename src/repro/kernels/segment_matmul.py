"""Pallas TPU kernel: GNN scatter-aggregation as blocked one-hot MXU matmul.

``out[n] = Σ_{e: dst[e]==n} messages[e]`` — the message-passing primitive.
The scatter-free TPU formulation: for a node tile ``[bN]`` and an edge tile
``[bE]``, build the dense one-hot ``[bN, bE]`` (``dst[e] == n``) and issue
``one_hot @ messages`` on the MXU, accumulating over the edge grid axis
(output tile revisited with ``+=``, zero-initialized at the first edge
step).  This converts irregular scatter into dense matmuls — the standard
MXU trick (GE-SpMM-style, adapted to the systolic array).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_E = 256


def _kernel(dst_ref, msg_ref, out_ref, *, block_n):
    j = pl.program_id(1)                  # edge-tile index (reduction axis)
    i = pl.program_id(0)                  # node-tile index

    @pl.when(j == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = dst_ref[...]                    # [bE] int32 (global node ids)
    msg = msg_ref[...]                    # [bE, D]
    node_ids = i * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (block_n, dst.shape[0]), 0)
    one_hot = (node_ids == dst[None, :]).astype(msg.dtype)   # [bN, bE]
    out_ref[...] += jax.lax.dot(one_hot, msg,
                                preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("num_nodes", "block_n", "block_e",
                                    "interpret"))
def segment_matmul(messages: jnp.ndarray, dst: jnp.ndarray, num_nodes: int,
                   block_n: int = DEFAULT_BLOCK_N,
                   block_e: int = DEFAULT_BLOCK_E,
                   interpret: bool = True) -> jnp.ndarray:
    """Segment-sum of ``messages [E, D]`` by ``dst [E]`` into [N, D] fp32."""
    e, d = messages.shape
    bn = min(block_n, num_nodes)
    be = min(block_e, e)
    pad_n = (-num_nodes) % bn
    pad_e = (-e) % be
    if pad_e:
        messages = jnp.pad(messages, ((0, pad_e), (0, 0)))
        dst = jnp.pad(dst, (0, pad_e), constant_values=-1)   # matches no node
    np_, ep = num_nodes + pad_n, e + pad_e

    out = pl.pallas_call(
        functools.partial(_kernel, block_n=bn),
        grid=(np_ // bn, ep // be),
        in_specs=[
            pl.BlockSpec((be,), lambda i, j: (j,)),
            pl.BlockSpec((be, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, d), jnp.float32),
        interpret=interpret,
    )(dst, messages)
    return out[:num_nodes]
