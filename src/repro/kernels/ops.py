"""Jit'd public wrappers for the Pallas kernels.

On this CPU container kernels run under ``interpret=True`` (Pallas executes
the kernel body in Python per grid step — bitwise-identical semantics);
on TPU set ``REPRO_PALLAS_COMPILE=1`` to lower them for real.
"""
from __future__ import annotations

import os

import jax

from .embedding_bag import embedding_bag as _embedding_bag
from .flash_attention import flash_attention as _flash_attention
from .frontier_expand import frontier_expand as _frontier_expand
from .segment_matmul import segment_matmul as _segment_matmul


def _interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE") == "1":
        return False
    return jax.default_backend() != "tpu"


def frontier_expand(p_bits, ext_bits, **kw):
    kw.setdefault("interpret", _interpret())
    return _frontier_expand(p_bits, ext_bits, **kw)


def segment_matmul(messages, dst, num_nodes, **kw):
    kw.setdefault("interpret", _interpret())
    return _segment_matmul(messages, dst, num_nodes=num_nodes, **kw)


def embedding_bag(table, ids, **kw):
    kw.setdefault("interpret", _interpret())
    return _embedding_bag(table, ids, **kw)


def flash_attention(q, k, v, causal=True, **kw):
    kw.setdefault("interpret", _interpret())
    return _flash_attention(q, k, v, causal=causal, **kw)
