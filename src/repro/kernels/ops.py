"""Jit'd public wrappers for the Pallas kernels.

Execution mode is auto-detected per kernel call (``interpret=None`` →
:func:`repro.kernels.runtime.default_interpret`): kernels lower for real
on TPU and run under the Pallas interpreter elsewhere (bitwise-identical
semantics, CPU CI).  Set ``REPRO_PALLAS_COMPILE=1`` to force real
lowering regardless of backend; pass ``interpret=...`` explicitly to pin
one call.
"""
from __future__ import annotations

from .embedding_bag import embedding_bag as _embedding_bag
from .flash_attention import flash_attention as _flash_attention
from .frontier_expand import frontier_expand as _frontier_expand
from .masked_intersect import masked_intersect as _masked_intersect
from .runtime import default_interpret as _interpret
from .segment_matmul import segment_matmul as _segment_matmul


def masked_intersect(a_bits, b_bits, mask_bits=None, **kw):
    return _masked_intersect(a_bits, b_bits, mask_bits, **kw)


def frontier_expand(p_bits, ext_bits, **kw):
    return _frontier_expand(p_bits, ext_bits, **kw)


def segment_matmul(messages, dst, num_nodes, **kw):
    kw.setdefault("interpret", _interpret())
    return _segment_matmul(messages, dst, num_nodes=num_nodes, **kw)


def embedding_bag(table, ids, **kw):
    kw.setdefault("interpret", _interpret())
    return _embedding_bag(table, ids, **kw)


def flash_attention(q, k, v, causal=True, **kw):
    kw.setdefault("interpret", _interpret())
    return _flash_attention(q, k, v, causal=causal, **kw)
