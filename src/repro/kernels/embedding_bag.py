"""Pallas TPU kernel: recsys embedding lookup (EmbeddingBag's gather half).

JAX has no ``nn.EmbeddingBag``; the wide-deep hot path is a per-field
gather from huge tables.  TPU mapping: the grid iterates (batch-tile,
field); ids are **scalar-prefetched** so the BlockSpec ``index_map`` itself
selects which table row block to DMA — the canonical TPU embedding pattern
(the row fetch is issued by the pipeline, not by in-kernel control flow).
One grid step copies the ``[1, D]`` row of ``table[f, ids[b, f]]`` into the
output tile; the multi-hot "bag" reduction composes with
:mod:`repro.kernels.segment_matmul`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, table_ref, out_ref):
    out_ref[...] = table_ref[...]        # row already selected by index_map


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  interpret: bool = True) -> jnp.ndarray:
    """table: [F, V, D]; ids: [B, F] int32 → [B, F*D] fp32.

    Grid (B, F); the table BlockSpec's index_map reads the prefetched ids to
    pick (field, row); the output BlockSpec places the row at (b, f).
    """
    f, v, d = table.shape
    b, f2 = ids.shape
    assert f == f2

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, f),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda i, j, ids: (j, ids[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, j, ids: (i, j, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, f, d), table.dtype),
        interpret=interpret,
    )(ids, table)
    return out.reshape(b, f * d).astype(jnp.float32)
