"""Pallas execution-mode selection shared by every kernel wrapper
(docs/KERNELS.md, DESIGN.md §10).

Every kernel in this package takes ``interpret: Optional[bool]`` with a
``None`` default meaning *auto-detect*: lower for real on TPU, run the
Pallas interpreter everywhere else (CPU CI, laptops).  The old behavior —
a hardcoded ``interpret=True`` — silently ran the interpreter on TPU
unless the caller remembered to flip it; auto-detection makes the fast
path the default on the hardware that has one while keeping CPU tests
hermetic.

``REPRO_PALLAS_COMPILE=1`` forces real lowering regardless of backend
(useful for Pallas-on-CPU lowering experiments and for asserting that a
TPU job is *not* in interpreter mode).
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def default_interpret() -> bool:
    """True iff Pallas kernels should run in interpreter mode here."""
    if os.environ.get("REPRO_PALLAS_COMPILE") == "1":
        return False
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve a kernel's ``interpret`` argument (None = auto-detect)."""
    return default_interpret() if interpret is None else bool(interpret)
