"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``<name>`` in this package has ``ref.<name>_ref`` with identical
signature/semantics; kernel tests sweep shapes/dtypes and assert_allclose
against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_intersect_ref(a_bits: jnp.ndarray, b_bits: jnp.ndarray,
                         mask_bits=None):
    """counts[r, c] = popcount(a[r] & mask[r] & b[c]).

    a_bits/mask_bits: [B, W] uint32 rows (mask None = all-ones);
    b_bits: [N, W] uint32 columns.  Returns [B, N] int32.  Materializes
    the full [B, N, W] intersection — the capacity-bound allocation the
    Pallas tiling avoids (docs/KERNELS.md).
    """
    rows = a_bits if mask_bits is None else a_bits & mask_bits
    inter = rows[:, None, :] & b_bits[None, :, :]
    return jnp.sum(jax.lax.population_count(inter).astype(jnp.int32),
                   axis=-1)


def frontier_expand_ref(p_bits: jnp.ndarray, ext_bits: jnp.ndarray):
    """counts[b, v] = popcount(p_bits[b] & ext_bits[v]).

    p_bits: [B, W] uint32 candidate bitsets; ext_bits: [N, W] uint32
    (adjacency ∩ {u > v} masks).  Returns [B, N] int32.
    """
    inter = p_bits[:, None, :] & ext_bits[None, :, :]
    return jnp.sum(jax.lax.population_count(inter).astype(jnp.int32),
                   axis=-1)


def segment_matmul_ref(messages: jnp.ndarray, dst: jnp.ndarray,
                       num_nodes: int):
    """out[n] = Σ_{e: dst[e]==n} messages[e].  messages: [E, D]; dst: [E]."""
    return jax.ops.segment_sum(messages.astype(jnp.float32), dst,
                               num_segments=num_nodes)


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray):
    """table: [F, V, D]; ids: [B, F] → [B, F*D] (per-field gather concat)."""
    b, f = ids.shape
    emb = table[jnp.arange(f)[None, :], ids]        # [B, F, D]
    return emb.reshape(b, -1)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True):
    """q/k/v: [H, S, D] → [H, S, D] (fp32 softmax attention)."""
    h, s, d = q.shape
    scores = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32))
