"""Service-layer benchmark: query throughput and cache-hit latency.

Measures:

1. **cold queries/sec** — N distinct clique queries (varying k) executed
   by the round-robin scheduler in one batch, vs. the same N queries run
   sequentially through dedicated ``Engine.run()`` calls;
2. **cache-hit latency** — repeated identical requests served from the
   LRU+TTL result cache (no engine steps).

    PYTHONPATH=src python benchmarks/bench_service.py [--n-queries 8]
"""
from __future__ import annotations

import argparse
import time

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.data.synthetic_graphs import planted_clique_graph
from repro.service import DiscoveryRequest, DiscoveryService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200, help="graph vertices")
    ap.add_argument("--m", type=int, default=1200, help="graph edges")
    ap.add_argument("--n-queries", type=int, default=8)
    ap.add_argument("--hits", type=int, default=200,
                    help="cache-hit repetitions to time")
    args = ap.parse_args()

    g = planted_clique_graph(n=args.n, m=args.m, clique_size=7, seed=7)
    requests = [
        DiscoveryRequest(graph="bench", workload="clique", k=1 + i,
                         request_id=f"q{i}")
        for i in range(args.n_queries)
    ]

    # --- sequential reference: one dedicated engine per query ------------
    comp = make_clique_computation(g)
    t0 = time.perf_counter()
    seq_results = [
        Engine(comp, EngineConfig(k=r.k, batch=r.batch,
                                  pool_capacity=r.pool_capacity)).run()
        for r in requests
    ]
    seq_s = time.perf_counter() - t0

    # --- scheduled batch -------------------------------------------------
    svc = DiscoveryService()
    svc.register_graph("bench", g)
    t0 = time.perf_counter()
    responses = svc.serve(requests)
    sched_s = time.perf_counter() - t0

    for resp, ref in zip(responses, seq_results):
        assert resp.result_keys == [int(x) for x in ref.result_keys], \
            f"{resp.request_id}: scheduler result diverged"

    # --- cache hits ------------------------------------------------------
    t0 = time.perf_counter()
    for _ in range(args.hits):
        hit = svc.query(requests[0])
        assert hit.cached
    hit_s = (time.perf_counter() - t0) / args.hits

    q = args.n_queries
    print(f"[bench_service] graph n={args.n} m={args.m}, {q} clique queries")
    print(f"  sequential Engine.run : {seq_s:.2f}s "
          f"({q / seq_s:.2f} queries/s)")
    print(f"  scheduled batch       : {sched_s:.2f}s "
          f"({q / sched_s:.2f} queries/s, "
          f"{svc.engine_steps_total} engine steps)")
    print(f"  cache hit             : {hit_s * 1e6:.0f}us/query "
          f"({1 / hit_s:.0f} queries/s)")


if __name__ == "__main__":
    main()
