"""Service-layer benchmark: query throughput and cache-hit latency.

Measures:

1. **cold queries/sec** — N distinct clique queries (varying k) executed
   by the round-robin scheduler in one batch, vs. the same N queries run
   sequentially through dedicated ``Engine.run()`` calls;
2. **cache-hit latency** — repeated identical requests served from the
   LRU+TTL result cache (no engine steps).

Registered in the harness (``python -m benchmarks.run``) and runnable
alone:

    PYTHONPATH=src python benchmarks/bench_service.py [--n-queries 8]
"""
from __future__ import annotations

import argparse
import time

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.data.synthetic_graphs import planted_clique_graph
from repro.service import DiscoveryRequest, DiscoveryService


def run(n: int = 200, m: int = 1200, n_queries: int = 8,
        hits: int = 200) -> dict:
    g = planted_clique_graph(n=n, m=m, clique_size=7, seed=7)
    requests = [
        DiscoveryRequest(graph="bench", workload="clique", k=1 + i,
                         request_id=f"q{i}")
        for i in range(n_queries)
    ]

    # --- sequential reference: one dedicated engine per query ------------
    comp = make_clique_computation(g)
    t0 = time.perf_counter()
    seq_results = [
        Engine(comp, EngineConfig(k=r.k, batch=r.batch,
                                  pool_capacity=r.pool_capacity)).run()
        for r in requests
    ]
    seq_s = time.perf_counter() - t0

    # --- scheduled batch -------------------------------------------------
    svc = DiscoveryService()
    svc.register_graph("bench", g)
    t0 = time.perf_counter()
    responses = svc.serve(requests)
    sched_s = time.perf_counter() - t0

    for resp, ref in zip(responses, seq_results):
        assert resp.result_keys == [int(x) for x in ref.result_keys], \
            f"{resp.request_id}: scheduler result diverged"

    # --- cache hits ------------------------------------------------------
    t0 = time.perf_counter()
    for _ in range(hits):
        hit = svc.query(requests[0])
        assert hit.cached
    hit_s = (time.perf_counter() - t0) / hits

    print(f"[bench_service] graph n={n} m={m}, {n_queries} clique queries")
    print(f"  sequential Engine.run : {seq_s:.2f}s "
          f"({n_queries / seq_s:.2f} queries/s)")
    print(f"  scheduled batch       : {sched_s:.2f}s "
          f"({n_queries / sched_s:.2f} queries/s, "
          f"{svc.engine_steps_total} engine steps)")
    print(f"  cache hit             : {hit_s * 1e6:.0f}us/query "
          f"({1 / hit_s:.0f} queries/s)")
    return dict(
        n=n, m=m, n_queries=n_queries,
        sequential_s=round(seq_s, 3),
        sequential_qps=round(n_queries / seq_s, 3),
        scheduled_s=round(sched_s, 3),
        scheduled_qps=round(n_queries / sched_s, 3),
        engine_steps=svc.engine_steps_total,
        cache_hit_us=round(hit_s * 1e6, 1),
        cache_hit_qps=round(1 / hit_s, 1))


def main(fast: bool = False) -> dict:
    """Harness entry point (``benchmarks/run.py``)."""
    if fast:
        return run(n=120, m=700, n_queries=4, hits=50)
    return run()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200, help="graph vertices")
    ap.add_argument("--m", type=int, default=1200, help="graph edges")
    ap.add_argument("--n-queries", type=int, default=8)
    ap.add_argument("--hits", type=int, default=200,
                    help="cache-hit repetitions to time")
    args = ap.parse_args()
    run(n=args.n, m=args.m, n_queries=args.n_queries, hits=args.hits)
