"""Figure 18 reproduction: effect of the result-set size k."""
import time

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.data.synthetic_graphs import densifying_graph


def run(n=200, m=900, ks=(1, 10, 100, 1000), seed=0):
    g = densifying_graph(n, m, seed)
    comp = make_clique_computation(g)
    rows = []
    for k in ks:
        t0 = time.time()
        res = Engine(comp, EngineConfig(k=k, batch=64,
                                        pool_capacity=max(16384, 4 * k),
                                        max_steps=200000)).run()
        rows.append(dict(k=k, candidates=res.candidates,
                         s=round(time.time() - t0, 3),
                         pruned=res.pruned))
    return rows


def main(fast: bool = False):
    rows = run(ks=(1, 10, 100) if fast else (1, 10, 100, 1000))
    print(f"{'k':>5} {'candidates':>11} {'pruned':>8} {'s':>7}")
    for r in rows:
        print(f"{r['k']:>5} {r['candidates']:>11} {r['pruned']:>8} "
              f"{r['s']:>7.2f}")
    return rows


if __name__ == "__main__":
    main()
