"""Label-selectivity sweep: host-side filtering vs predicate pushdown
(DESIGN.md §12).

The attributed generator's skewed labels make the allowed-label set a
selectivity dial: allowing only tail labels leaves few eligible vertices
(low selectivity), which is where pushdown pays — the predicate lands in
the kernel constraint mask *and* the priority index, so label-infeasible
states are dominance-pruned before expansion instead of materialized and
filtered.  Both modes are asserted byte-identical per point (top-k keys
and states for iso; patterns, supports, and group structure for mining),
so every number below is the cost of the *same* answer.

Off-TPU both modes run the same batched jnp reference path, so the
wall-clock compares the algorithmic placement of the filter, not kernel
speed (docs/KERNELS.md); a ``use_pallas`` pushdown run is parity-checked
per point as well.
"""
import time

import numpy as np

from repro.core.aggregate import topk_frequent_patterns
from repro.core.engine import Engine, EngineConfig
from repro.core.iso import build_iso_index, make_iso_computation
from repro.core.labels import LabelPredicate
from repro.data.synthetic_graphs import attributed_graph


def _allowed_sets(g, fractions):
    """Allowed-label tail sets whose vertex coverage is closest to each
    target fraction (labels sorted by frequency, rarest first)."""
    labels = np.asarray(g.labels)
    counts = np.bincount(labels, minlength=g.n_labels)
    order = np.argsort(counts)                    # rarest label first
    cum = np.cumsum(counts[order]) / g.n
    out = []
    for frac in fractions:
        j = int(np.searchsorted(cum, frac)) + 1
        allowed = tuple(sorted(int(l) for l in order[:j]))
        out.append((allowed, float(cum[j - 1])))
    return out


def run_iso(n=240, m=1200, n_labels=8, k=5, seed=3,
            fractions=(0.1, 0.3, 1.0)):
    """Triangle query over label classes = the allowed set, post vs
    pushdown, parity asserted; returns one row per selectivity point."""
    g = attributed_graph(n, m, n_labels, seed=seed)
    index = build_iso_index(g, max_hops=2)
    q_edges = [(0, 1), (1, 2), (0, 2)]
    cfg = EngineConfig(k=k, batch=32, pool_capacity=4096, max_steps=100_000)
    rows = []
    for allowed, sel in _allowed_sets(g, fractions):
        pred = LabelPredicate.from_spec(dict(
            vertex_any_of=list(allowed),
            q_any_of=[list(allowed)] * 3))
        q_labels = [allowed[0]] * 3   # overridden per-slot by q_any_of

        def build(label_filter, use_pallas=False):
            return make_iso_computation(
                g, q_edges, q_labels, index, predicate=pred,
                label_filter=label_filter, use_pallas=use_pallas)

        t0 = time.time()
        post = Engine(build("post"), cfg).run()
        t_post = time.time() - t0
        t0 = time.time()
        push = Engine(build("pushdown"), cfg).run()
        t_push = time.time() - t0
        assert np.array_equal(post.result_keys, push.result_keys), \
            (sel, post.result_keys, push.result_keys)
        assert np.array_equal(post.result_states, push.result_states), sel
        kern = Engine(build("pushdown", use_pallas=True), cfg).run()
        assert np.array_equal(push.result_keys, kern.result_keys), sel
        assert np.array_equal(push.result_states, kern.result_states), sel
        rows.append(dict(
            workload="iso", selectivity=round(sel, 3),
            allowed_labels=len(allowed),
            host_filter_candidates=post.candidates,
            pushdown_candidates=push.candidates,
            host_filter_steps=post.steps, pushdown_steps=push.steps,
            host_filter_s=round(t_post, 3), pushdown_s=round(t_push, 3),
            parity="ok"))
    low = rows[0]
    assert low["pushdown_candidates"] <= low["host_filter_candidates"], low
    return rows


def run_pattern(n=140, m=560, n_labels=6, m_edges=3, k=3, seed=4,
                fractions=(0.15, 0.4, 1.0)):
    """Top-k frequent mining under a vertex predicate, post vs pushdown.
    Candidate counts differ by construction (post materializes-then-
    filters every extension); patterns and supports must not."""
    g = attributed_graph(n, m, n_labels, seed=seed)
    rows = []
    for allowed, sel in _allowed_sets(g, fractions):
        pred = LabelPredicate.from_spec(dict(vertex_any_of=list(allowed)))
        t0 = time.time()
        post = topk_frequent_patterns(g, m_edges, k=k, predicate=pred,
                                      label_filter="post")
        t_post = time.time() - t0
        t0 = time.time()
        push = topk_frequent_patterns(g, m_edges, k=k, predicate=pred,
                                      label_filter="pushdown")
        t_push = time.time() - t0
        assert post.patterns == push.patterns, (sel, post.patterns,
                                               push.patterns)
        assert push.candidates <= post.candidates, sel
        rows.append(dict(
            workload="pattern", selectivity=round(sel, 3),
            allowed_labels=len(allowed),
            host_filter_candidates=post.candidates,
            pushdown_candidates=push.candidates,
            host_filter_s=round(t_post, 3), pushdown_s=round(t_push, 3),
            parity="ok"))
    return rows


def _print(rows):
    print(f"{'workload':>8} {'sel':>5} {'host cand':>10} {'push cand':>10} "
          f"{'host s':>7} {'push s':>7}")
    for r in rows:
        print(f"{r['workload']:>8} {r['selectivity']:>5.2f} "
              f"{r['host_filter_candidates']:>10} "
              f"{r['pushdown_candidates']:>10} "
              f"{r['host_filter_s']:>7.2f} {r['pushdown_s']:>7.2f}")


def main(fast: bool = False):
    iso_rows = run_iso(n=120 if fast else 240, m=560 if fast else 1200,
                       fractions=(0.1, 1.0) if fast else (0.1, 0.3, 1.0))
    pat_rows = run_pattern(n=90 if fast else 140, m=340 if fast else 560,
                           m_edges=2 if fast else 3,
                           fractions=(0.15, 1.0) if fast else
                           (0.15, 0.4, 1.0))
    rows = iso_rows + pat_rows
    _print(rows)
    low = [r for r in rows if r["workload"] == "pattern"][0]
    print(f"\nlowest-selectivity pattern point: pushdown creates "
          f"{low['pushdown_candidates']} candidates vs "
          f"{low['host_filter_candidates']} host-filtered "
          f"({low['host_filter_candidates'] / max(low['pushdown_candidates'], 1):.2f}x)")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    main(fast=ap.parse_args().fast)
