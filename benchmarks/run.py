"""Benchmark harness: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import bench_checkpoint, bench_clique, bench_distributed, \
    bench_engine, bench_iso, bench_k, bench_labeled, bench_pattern, \
    bench_service, bench_vpq  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write per-benchmark wall-clock timings + "
                         "result rows to PATH (e.g. BENCH_PR6.json) — the "
                         "perf-trajectory artifact CI uploads")
    ap.add_argument("--only", default=None, metavar="SUBSTR",
                    help="run only benchmarks whose registry name contains "
                         "SUBSTR (e.g. 'distributed' for the stale-bound "
                         "K-sweep artifact)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    results = {}
    timings = {}
    for name, mod in [("clique (Fig 9-11)", bench_clique),
                      ("pattern (Fig 12-14)", bench_pattern),
                      ("iso (Fig 15-17)", bench_iso),
                      ("k-sweep (Fig 18)", bench_k),
                      ("vpq (Fig 19)", bench_vpq),
                      ("service (§9)", bench_service),
                      ("distributed (§11)", bench_distributed),
                      ("labeled (§12)", bench_labeled),
                      ("engine macro-step (§13)", bench_engine),
                      ("checkpoint (§15)", bench_checkpoint)]:
        if args.only and args.only not in name:
            continue
        print(f"\n=== {name} ===")
        t0 = time.time()
        results[name] = mod.main(fast=args.fast)
        timings[name] = round(time.time() - t0, 3)
        print(f"[{name}] {timings[name]:.1f}s")
    with open(os.path.join(args.out, "results.json"), "w") as f:
        json.dump(results, f, indent=1, default=str)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"fast": args.fast,
                       "total_seconds": round(sum(timings.values()), 3),
                       "benchmarks": {
                           name: {"seconds": timings[name],
                                  "results": results[name]}
                           for name in results}},
                      f, indent=1, default=str)
        print(f"per-benchmark timings written to {args.json}")
    print("\nbenchmarks complete.")


if __name__ == "__main__":
    main()
