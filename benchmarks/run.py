"""Benchmark harness: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

``--json PATH`` writes this run's per-benchmark timings + result rows to
PATH (the per-PR artifact CI uploads) AND appends the run's numeric cells
to the cumulative ``BENCH_TRAJECTORY.jsonl`` — one
``{"pr", "benchmark", "cell", "value"}`` row per measurement, deduped by
(pr, benchmark, cell) with newest-wins, so the perf trajectory across
PRs lives in one greppable file.  ``--backfill F.json [G.json ...]``
ingests existing per-PR artifacts into the trajectory without running
anything.
"""
import argparse
import json
import os
import re
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import bench_checkpoint, bench_clique, bench_distributed, \
    bench_engine, bench_iso, bench_k, bench_labeled, bench_obs, \
    bench_pattern, bench_service, bench_vpq  # noqa: E402

REGISTRY = [("clique (Fig 9-11)", bench_clique),
            ("pattern (Fig 12-14)", bench_pattern),
            ("iso (Fig 15-17)", bench_iso),
            ("k-sweep (Fig 18)", bench_k),
            ("vpq (Fig 19)", bench_vpq),
            ("service (§9)", bench_service),
            ("distributed (§11)", bench_distributed),
            ("labeled (§12)", bench_labeled),
            ("engine macro-step (§13)", bench_engine),
            ("checkpoint (§15)", bench_checkpoint),
            ("observability (§16)", bench_obs)]

# keys that *identify* a result row rather than measure it — they name
# the trajectory cell so the same configuration is comparable across PRs
ID_KEYS = ("workload", "spill", "checkpoint_every", "observe", "T",
           "shards", "sync_every", "devices", "n", "m", "k", "clusters",
           "steps_per_sync", "skew", "every", "kernel", "mode", "graph")


def _cells(obj, prefix=""):
    """Flatten a benchmark's result structure (list-of-row-dicts, nested
    dicts, or any mix) into ``(cell, value)`` pairs over numeric leaves."""
    if isinstance(obj, dict):
        ident = ",".join(f"{k}={obj[k]}" for k in ID_KEYS if k in obj)
        base = f"{prefix}{ident}:" if ident else prefix
        for k, v in obj.items():
            if k in ID_KEYS:
                continue
            if isinstance(v, bool):
                continue
            if isinstance(v, (int, float)):
                yield f"{base}{k}", v
            elif isinstance(v, (dict, list)):
                yield from _cells(v, prefix=f"{base}{k}.")
    elif isinstance(obj, list):
        for i, item in enumerate(obj):
            if isinstance(item, dict) and any(k in item for k in ID_KEYS):
                yield from _cells(item, prefix=prefix)   # self-identifying
            elif isinstance(item, (dict, list)):
                yield from _cells(item, prefix=f"{prefix}{i}.")


def trajectory_rows(pr: str, benchmarks: dict) -> list:
    """``{pr, benchmark, cell, value}`` rows from a per-PR artifact's
    ``benchmarks`` mapping (name -> {seconds, results})."""
    rows = []
    for name, entry in benchmarks.items():
        rows.append({"pr": pr, "benchmark": name, "cell": "seconds",
                     "value": entry["seconds"]})
        for cell, value in _cells(entry.get("results")):
            rows.append({"pr": pr, "benchmark": name, "cell": cell,
                         "value": value})
    return rows


def append_trajectory(path: str, rows: list) -> int:
    """Merge ``rows`` into the cumulative JSONL, deduped by
    (pr, benchmark, cell) — a re-run of the same PR's sweep replaces its
    old rows in place.  Returns the file's row count after the merge."""
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    r = json.loads(line)
                    merged[r["pr"], r["benchmark"], r["cell"]] = r
    for r in rows:
        merged[r["pr"], r["benchmark"], r["cell"]] = r
    ordered = sorted(merged.values(),
                     key=lambda r: (r["pr"], r["benchmark"], r["cell"]))
    with open(path, "w") as f:
        for r in ordered:
            f.write(json.dumps(r) + "\n")
    return len(ordered)


def _pr_label(json_path: str) -> str:
    m = re.search(r"PR(\d+)", os.path.basename(json_path))
    return f"PR{m.group(1)}" if m else "dev"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write per-benchmark wall-clock timings + "
                         "result rows to PATH (e.g. BENCH_PR8.json) — the "
                         "perf-trajectory artifact CI uploads; its cells "
                         "are appended to --trajectory too")
    ap.add_argument("--only", default=None, metavar="SUBSTR",
                    help="run only benchmarks whose registry name contains "
                         "SUBSTR (e.g. 'distributed' for the stale-bound "
                         "K-sweep artifact)")
    ap.add_argument("--trajectory", default="BENCH_TRAJECTORY.jsonl",
                    metavar="PATH",
                    help="cumulative cross-PR trajectory JSONL "
                         "(set empty to skip)")
    ap.add_argument("--pr", default=None, metavar="LABEL",
                    help="trajectory PR label (default: PR<N> parsed from "
                         "the --json filename, else 'dev')")
    ap.add_argument("--backfill", nargs="+", default=None, metavar="JSON",
                    help="ingest existing per-PR artifacts (BENCH_PR*.json) "
                         "into --trajectory and exit without benchmarking")
    args = ap.parse_args()

    if args.backfill:
        rows = []
        for path in args.backfill:
            with open(path) as f:
                doc = json.load(f)
            rows += trajectory_rows(args.pr or _pr_label(path),
                                    doc["benchmarks"])
        total = append_trajectory(args.trajectory, rows)
        print(f"backfilled {len(rows)} rows from {len(args.backfill)} "
              f"artifact(s); {args.trajectory} now has {total} rows")
        return

    os.makedirs(args.out, exist_ok=True)
    results = {}
    timings = {}
    for name, mod in REGISTRY:
        if args.only and args.only not in name:
            continue
        print(f"\n=== {name} ===")
        t0 = time.time()
        results[name] = mod.main(fast=args.fast)
        timings[name] = round(time.time() - t0, 3)
        print(f"[{name}] {timings[name]:.1f}s")
    with open(os.path.join(args.out, "results.json"), "w") as f:
        json.dump(results, f, indent=1, default=str)
    if args.json:
        benchmarks = {name: {"seconds": timings[name],
                             "results": results[name]}
                      for name in results}
        with open(args.json, "w") as f:
            json.dump({"fast": args.fast,
                       "total_seconds": round(sum(timings.values()), 3),
                       "benchmarks": benchmarks},
                      f, indent=1, default=str)
        print(f"per-benchmark timings written to {args.json}")
        if args.trajectory:
            rows = trajectory_rows(args.pr or _pr_label(args.json),
                                   benchmarks)
            total = append_trajectory(args.trajectory, rows)
            print(f"{len(rows)} trajectory rows appended to "
                  f"{args.trajectory} ({total} total)")
    print("\nbenchmarks complete.")


if __name__ == "__main__":
    main()
