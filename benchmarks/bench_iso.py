"""Figure 15/16/17 reproduction: top-k subgraph isomorphism.

Query types 2 / 3P / 3C / 4P / 4C / 4G (paper §6.4) on a labeled graph;
Nuri vs Nuri-NP (no index pruning → upper bound = +inf) vs exhaustive
counting; plus the selectivity sweep (Fig 17): non-selective vs selective
queries; plus the kernel-vs-reference mode (:func:`run_candidate_paths`):
per-state-loop vs batched vs Pallas candidate generation on one dequeued
batch, with engine-level result parity asserted (docs/KERNELS.md).
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import Engine, EngineConfig
from repro.core.exhaustive import brute_force_iso
from repro.core.iso import build_iso_index, make_iso_computation
from repro.data.synthetic_graphs import labeled_graph

QUERY_TYPES = {
    "2":  ([(0, 1)], 2),
    "3P": ([(0, 1), (1, 2)], 3),
    "3C": ([(0, 1), (1, 2), (0, 2)], 3),
    "4P": ([(0, 1), (1, 2), (2, 3)], 4),
    "4C": ([(0, 1), (1, 2), (2, 3), (0, 3), (0, 2), (1, 3)], 4),
    "4G": ([(0, 1), (1, 2), (2, 3), (1, 3)], 4),
}


def _sample_query_labels(g, nq, seed):
    """Labels sampled from the data graph so matches exist (paper's
    random-walk sampling stand-in)."""
    rng = np.random.default_rng(seed)
    return [int(g.labels[rng.integers(0, g.n)]) for _ in range(nq)]


def run(n=150, m=500, n_labels=3, k=1, seed=0, samples=3):
    g = labeled_graph(n, m, n_labels, seed)
    index = build_iso_index(g, max_hops=3)
    rows = []
    for qname, (q_edges, nq) in QUERY_TYPES.items():
        cands, times, matches = [], [], []
        for s in range(samples):
            q_labels = _sample_query_labels(g, nq, seed + s)
            comp = make_iso_computation(g, q_edges, q_labels, index)
            t0 = time.time()
            res = Engine(comp, EngineConfig(
                k=k, batch=64, pool_capacity=16384,
                max_steps=100000)).run()
            times.append(time.time() - t0)
            cands.append(res.candidates)
            matches.append(int(res.result_keys[0] > -2**31 + 1))
        rows.append(dict(query=qname, mean_candidates=float(np.mean(cands)),
                         mean_s=float(np.mean(times)),
                         found=int(np.sum(matches))))
    return rows


def run_selectivity(n=150, m=500, seed=0):
    """Fig 17: vary label diversity — few labels = non-selective (many
    matches), many labels = highly selective."""
    rows = []
    for n_labels, tag in ((2, "Q1 non-selective"), (5, "Q2 mild"),
                          (12, "Q3 selective")):
        g = labeled_graph(n, m, n_labels, seed)
        index = build_iso_index(g, max_hops=3)
        q_edges = [(0, 1), (1, 2)]
        q_labels = _sample_query_labels(g, 3, seed)
        comp = make_iso_computation(g, q_edges, q_labels, index)
        t0 = time.time()
        res = Engine(comp, EngineConfig(k=1, batch=64, pool_capacity=16384,
                                        max_steps=100000)).run()
        rows.append(dict(query=tag, candidates=res.candidates,
                         s=round(time.time() - t0, 3),
                         pruned=res.pruned))
    return rows


CAND_PATHS = (
    ("per-state loop", dict(cand_path="map")),
    ("vmapped loop", dict(cand_path="vmap")),
    ("batched jnp", {}),
    ("pallas kernel", dict(use_pallas=True)),
)


def run_candidate_paths(n=150, m=500, n_labels=3, seed=0, batch=64,
                        repeats=20, rounds=5):
    """Kernel-vs-reference mode: time one jitted ``score_children`` call —
    candidate generation for a whole dequeued batch — for each of the four
    paths, on the same [batch, S] state block, and assert that full engine
    runs return identical top-k results.

    The "per-state loop" row processes dequeued states one at a time
    (``lax.map`` — the paper's Algorithm-1 form, what targeted expansion
    looked like before batching); "vmapped loop" is the same per-state
    function batch-vectorized by ``vmap``; "batched jnp" is the one-shot
    constraint product (the kernel's reference semantics); "pallas
    kernel" additionally materializes the candidate grid through the
    masked-intersection kernel (interpreter mode off-TPU, so its
    wall-clock here is a correctness path, not a perf claim — see
    docs/KERNELS.md).
    """
    g = labeled_graph(n, m, n_labels, seed)
    index = build_iso_index(g, max_hops=3)
    q_edges, nq = QUERY_TYPES["4P"]
    q_labels = _sample_query_labels(g, nq, seed)
    rows, keys = [], {}
    for path, kw in CAND_PATHS:
        comp = make_iso_computation(g, q_edges, q_labels, index, **kw)
        states, _, _ = comp.init_frontier()
        reps = -(-batch // states.shape[0])          # tile seeds up to batch
        block = jnp.concatenate([states] * reps)[:batch]
        step = jax.jit(comp.score_children)
        jax.block_until_ready(step(block))           # compile + warm up
        best = float("inf")                          # best-of-rounds: these
        for _ in range(rounds):                      # calls are ~0.1 ms, so
            t0 = time.perf_counter()                 # min filters scheduler
            for _ in range(repeats):                 # noise out of the mean
                out = step(block)
            jax.block_until_ready(out)
            best = min(best, (time.perf_counter() - t0) / repeats)
        ms = best * 1e3
        res = Engine(comp, EngineConfig(k=3, batch=batch,
                                        pool_capacity=8192,
                                        max_steps=100000)).run()
        keys[path] = [int(x) for x in res.result_keys]
        rows.append(dict(path=path, ms_per_call=round(ms, 3),
                         result_keys=keys[path]))
    assert all(k == keys["per-state loop"] for k in keys.values()), \
        f"candidate paths disagree: {keys}"
    base = rows[0]["ms_per_call"]
    for r in rows:
        r["speedup_vs_loop"] = round(base / r["ms_per_call"], 2)
    return rows


def main(fast: bool = False):
    rows = run(n=100 if fast else 150, m=330 if fast else 500,
               samples=2 if fast else 3)
    print(f"{'query':>6} {'mean cand':>10} {'mean s':>8} {'found':>6}")
    for r in rows:
        print(f"{r['query']:>6} {r['mean_candidates']:>10.0f} "
              f"{r['mean_s']:>8.2f} {r['found']:>6}")
    sel = run_selectivity(n=100 if fast else 150, m=330 if fast else 500)
    print("\nselectivity (Fig 17):")
    for r in sel:
        print(f"  {r['query']:>18}: candidates={r['candidates']} "
              f"pruned={r['pruned']} t={r['s']}s")
    cand_batch = 64
    cand = run_candidate_paths(n=100 if fast else 150,
                               m=330 if fast else 500,
                               batch=cand_batch,
                               repeats=10 if fast else 20)
    print(f"\ncandidate generation (kernel-vs-reference, "
          f"batch={cand_batch}, 4P):")
    for r in cand:
        print(f"  {r['path']:>15}: {r['ms_per_call']:>8.2f} ms/call "
              f"({r['speedup_vs_loop']:>5.2f}x vs loop) "
              f"top-k={r['result_keys']}")
    return rows + sel + cand


if __name__ == "__main__":
    main()
