"""Figure 19 reproduction: virtual priority queue vs in-memory queue.

Enqueue N distinct subgraph-sized entries (growing phase), dequeue all
(shrinking phase).  Compares a pure in-memory heap (the paper's Java
PriorityQueue stand-in), the VPQ with host-DRAM runs, and the VPQ with
disk (memory-mapped) runs — the paper's actual on-disk design.

Also measures the *refill* pattern the engine actually issues
(DESIGN.md §13): engine-sized ``pop_chunk`` calls with a late-pruning
``min_ub`` threshold, exercising the vectorized blockwise k-way merge —
the path that replaced the per-entry Python heap loop.
"""
import heapq
import time

import numpy as np

from repro.core.vpq import VirtualPriorityQueue


def run(sizes=(100_000, 200_000, 400_000), state_width=24, seed=0,
        tmpdir=None):
    rows = []
    for n in sizes:
        rng = np.random.default_rng(seed)
        prios = rng.permutation(n).astype(np.int32)
        states = np.repeat(prios[:, None], state_width, 1).astype(np.int32)

        # in-memory heap baseline
        t0 = time.time()
        heap = list(zip((-prios).tolist(), range(n)))
        heapq.heapify(heap)
        t_mem_enq = time.time() - t0
        t0 = time.time()
        while heap:
            heapq.heappop(heap)
        t_mem_deq = time.time() - t0

        results = dict(n=n, mem_enqueue_s=round(t_mem_enq, 3),
                       mem_dequeue_s=round(t_mem_deq, 3))
        for backend in ("host", "disk"):
            vpq = VirtualPriorityQueue(
                state_width=state_width, backend=backend,
                spill_dir=tmpdir, run_flush_size=1 << 15)
            t0 = time.time()
            for i in range(0, n, 1 << 15):
                sl = slice(i, i + (1 << 15))
                vpq.maybe_push(states[sl], prios[sl], prios[sl])
            vpq._flush_pending()
            t_enq = time.time() - t0
            t0 = time.time()
            out_total, last = 0, None
            while len(vpq):
                _, p, _ = vpq.pop_chunk(1 << 14)
                assert last is None or p[0] <= last
                last = p[-1]
                out_total += len(p)
            t_deq = time.time() - t0
            assert out_total == n
            vpq.close()
            results[f"vpq_{backend}_enqueue_s"] = round(t_enq, 3)
            results[f"vpq_{backend}_dequeue_s"] = round(t_deq, 3)

            # engine-refill pattern: 2K-entry chunks with late dominance
            # pruning (drop the bottom half by ub) — the blockwise merge's
            # hot path during discovery runs
            vpq = VirtualPriorityQueue(
                state_width=state_width, backend=backend,
                spill_dir=tmpdir, run_flush_size=1 << 15)
            for i in range(0, n, 1 << 15):
                sl = slice(i, i + (1 << 15))
                vpq.maybe_push(states[sl], prios[sl], prios[sl])
            t0 = time.time()
            survived = 0
            while len(vpq):
                _, p, _ = vpq.pop_chunk(1 << 11, min_ub=n // 2)
                survived += len(p)
            t_refill = time.time() - t0
            assert survived == n - n // 2
            assert vpq.total_late_pruned == n // 2
            vpq.close()
            results[f"vpq_{backend}_refill_s"] = round(t_refill, 3)
        rows.append(results)
    return rows


def main(fast: bool = False):
    rows = run(sizes=(50_000, 100_000) if fast
               else (100_000, 200_000, 400_000))
    hdr = (f"{'N':>8} {'mem enq':>8} {'mem deq':>8} {'host enq':>9} "
           f"{'host deq':>9} {'disk enq':>9} {'disk deq':>9} "
           f"{'host ref':>9} {'disk ref':>9}")
    print(hdr)
    for r in rows:
        print(f"{r['n']:>8} {r['mem_enqueue_s']:>8.2f} "
              f"{r['mem_dequeue_s']:>8.2f} {r['vpq_host_enqueue_s']:>9.2f} "
              f"{r['vpq_host_dequeue_s']:>9.2f} "
              f"{r['vpq_disk_enqueue_s']:>9.2f} "
              f"{r['vpq_disk_dequeue_s']:>9.2f} "
              f"{r['vpq_host_refill_s']:>9.2f} "
              f"{r['vpq_disk_refill_s']:>9.2f}")
    return rows


if __name__ == "__main__":
    main()
