"""Figure 12/13/14 reproduction: top-k frequent pattern mining.

Nuri (prioritized groups, anti-monotone pruning, pattern-oriented
expansion) vs the Arabesque-style threshold baseline at T=µ (oracle
threshold) and T=µ/3 (realistic mis-set threshold); plus the
kernel-vs-reference mode (:func:`run_kernel_mode`): the same mining run
with rightmost-path edge probes on the numpy reference path vs the Pallas
masked-intersection path, result parity asserted (docs/KERNELS.md).
"""
import time

from repro.core.aggregate import (arabesque_style_mining,
                                  max_support_of_size,
                                  topk_frequent_patterns)
from repro.data.synthetic_graphs import labeled_graph


def run(n=120, m=420, n_labels=4, m_edges_list=(2, 3), seed=0):
    g = labeled_graph(n, m, n_labels, seed)
    rows = []
    for m_edges in m_edges_list:
        t0 = time.time()
        nuri = topk_frequent_patterns(g, m_edges, k=1)
        t_nuri = time.time() - t0
        mu = nuri.patterns[0][0]

        t0 = time.time()
        at_mu = arabesque_style_mining(g, m_edges, threshold=mu)
        t_mu = time.time() - t0
        t0 = time.time()
        at_mu3 = arabesque_style_mining(g, m_edges,
                                        threshold=max(1, mu // 3))
        t_mu3 = time.time() - t0
        rows.append(dict(
            m_edges=m_edges, mu=mu,
            nuri_candidates=nuri.candidates, nuri_s=round(t_nuri, 3),
            abq_mu_candidates=at_mu.candidates, abq_mu_s=round(t_mu, 3),
            abq_mu3_candidates=at_mu3.candidates,
            abq_mu3_completed=at_mu3.completed,
            abq_mu3_s=round(t_mu3, 3)))
    return rows


def run_kernel_mode(n=80, m=280, n_labels=4, m_edges=3, k=3, seed=0):
    """Kernel-vs-reference mode: identical mining runs, edge probes via
    numpy word-gathers vs the masked-intersection kernel.  Off-TPU the
    kernel runs in interpreter mode, so its wall-clock is a correctness
    check, not a perf claim (docs/KERNELS.md)."""
    g = labeled_graph(n, m, n_labels, seed)
    t0 = time.time()
    ref = topk_frequent_patterns(g, m_edges, k=k)
    t_ref = time.time() - t0
    t0 = time.time()
    ker = topk_frequent_patterns(g, m_edges, k=k, use_pallas=True)
    t_ker = time.time() - t0
    assert ref.patterns == ker.patterns, "kernel path changed the result"
    assert ref.candidates == ker.candidates
    return dict(m_edges=m_edges, candidates=ref.candidates,
                reference_s=round(t_ref, 3), pallas_s=round(t_ker, 3),
                parity="ok")


def main(fast: bool = False):
    rows = run(n=80 if fast else 120, m=280 if fast else 420,
               m_edges_list=(2,) if fast else (2, 3))
    print(f"{'M':>2} {'µ':>4} {'Nuri cand':>10} {'Abq-µ cand':>11} "
          f"{'Abq-µ/3 cand':>13} {'Nuri s':>7} {'Abq-µ s':>8} {'µ/3 s':>7}")
    for r in rows:
        print(f"{r['m_edges']:>2} {r['mu']:>4} {r['nuri_candidates']:>10} "
              f"{r['abq_mu_candidates']:>11} {r['abq_mu3_candidates']:>13} "
              f"{r['nuri_s']:>7.2f} {r['abq_mu_s']:>8.2f} "
              f"{r['abq_mu3_s']:>7.2f}")
    km = run_kernel_mode(n=60 if fast else 80, m=200 if fast else 280,
                         m_edges=2 if fast else 3)
    print(f"\nedge probes (kernel-vs-reference, M={km['m_edges']}): "
          f"reference {km['reference_s']}s, pallas {km['pallas_s']}s, "
          f"candidates={km['candidates']}, parity={km['parity']}")
    return rows + [km]


if __name__ == "__main__":
    main()
