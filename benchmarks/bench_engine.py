"""Macro-step engine benchmark: host-sync amortization (DESIGN.md §13).

Measures super-steps/sec and wall-clock for ``steps_per_sync`` ∈ {1, 4, 16}
on the clique and iso workloads, with both VPQ spill backends (``host`` and
``disk``).  Every fused run is parity-asserted byte-for-byte against the
unfused (``steps_per_sync=1``) run — macro-stepping is a pure dispatch
optimization, results never change on complete runs.

The workload shapes are deliberately small: the point of macro-stepping is
amortizing the *fixed* per-step host cost (jit dispatch, the blocking
``device_get`` of the stats, overflow ship-out), which dominates exactly
when the per-step device work is small — the regime the paper's
single-machine design targets ("a small number of disk seeks" between long
prioritized-expansion bursts).

    PYTHONPATH=src python -m benchmarks.bench_engine [--fast]
"""
import dataclasses
import tempfile
import time

import numpy as np

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.core.iso import build_iso_index, make_iso_computation
from repro.data.synthetic_graphs import densifying_graph, labeled_graph

_T_SWEEP = (1, 4, 16)


def _best_of(rounds, fn):
    best, out = None, None
    for _ in range(rounds):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best, out


def _sweep(name, comp, cfg, rounds, tmpdir):
    """T × spill-backend grid for one workload; parity-asserted vs T=1."""
    rows = []
    for backend in ("host", "disk"):
        bcfg = dataclasses.replace(
            cfg, spill=backend,
            spill_dir=tmpdir if backend == "disk" else None)
        ref = None
        base_sps = None
        for T in _T_SWEEP:
            eng = Engine(comp, dataclasses.replace(bcfg, steps_per_sync=T))
            eng.run()                         # warm the jit caches
            wall, res = _best_of(rounds, eng.run)
            if T == 1:
                ref = res
                base_sps = res.steps / wall
            else:
                assert np.array_equal(ref.result_keys, res.result_keys), \
                    f"{name}/{backend}: T={T} result keys diverged"
                assert np.array_equal(ref.result_states,
                                      res.result_states), \
                    f"{name}/{backend}: T={T} result states diverged"
            sps = res.steps / wall
            rows.append(dict(
                workload=name, spill=backend, steps_per_sync=T,
                wall_s=round(wall, 4), steps=res.steps,
                host_syncs=res.host_syncs,
                steps_per_sec=round(sps, 1),
                speedup_vs_T1=round(sps / base_sps, 2),
                spilled=res.spilled, refilled=res.refilled,
                late_pruned=res.late_pruned))
    return rows


def run(fast: bool = False, rounds: int = 3, tmpdir=None):
    own_tmp = tmpdir is None
    if own_tmp:
        tmp = tempfile.TemporaryDirectory(prefix="bench_engine_")
        tmpdir = tmp.name
    try:
        rows = []
        # clique: dense graph + tiny batch/pool -> a long prioritized run
        # (hundreds of super-steps) with real spill/refill traffic, where
        # per-step device work is far below the per-sync host cost
        m = 1200 if fast else 1600
        g = densifying_graph(96, m, seed=0)
        rows += _sweep(
            "clique", make_clique_computation(g),
            EngineConfig(k=3, batch=4, pool_capacity=128, max_steps=200_000),
            rounds, tmpdir)
        # iso: triangle query over a labeled graph, pool tight enough that
        # the seed frontier spills and late pruning triggers on refill
        gl = labeled_graph(n=64 if fast else 80, m=300 if fast else 480,
                           n_labels=3, seed=5)
        comp = make_iso_computation(
            gl, [(0, 1), (1, 2), (0, 2)], [1, 1, 1],
            build_iso_index(gl, max_hops=2))
        rows += _sweep(
            "iso", comp,
            EngineConfig(k=3, batch=4, pool_capacity=32, max_steps=200_000),
            rounds, tmpdir)
        return rows
    finally:
        if own_tmp:
            tmp.cleanup()


def main(fast: bool = False):
    rows = run(fast=fast)
    print("(top-k parity vs steps_per_sync=1 asserted on every row)")
    print(f"{'workload':>8} {'spill':>5} {'T':>3} {'steps':>6} {'hsync':>6} "
          f"{'wall s':>8} {'steps/s':>9} {'vs T=1':>7} {'spilled':>8} "
          f"{'late_pr':>8}")
    for r in rows:
        print(f"{r['workload']:>8} {r['spill']:>5} {r['steps_per_sync']:>3} "
              f"{r['steps']:>6} {r['host_syncs']:>6} {r['wall_s']:>8.3f} "
              f"{r['steps_per_sec']:>9.1f} {r['speedup_vs_T1']:>6.2f}x "
              f"{r['spilled']:>8} {r['late_pruned']:>8}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    main(fast=ap.parse_args().fast)
