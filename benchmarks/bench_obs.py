"""Observability overhead benchmark (DESIGN.md §16).

Measures the wall-clock cost of the metrics + span-tracing subsystem on
the clique/host-spill cell at fusion factors T ∈ {1, 16}:

* ``observe=off`` — the default no-op path: instrumented code holding
  shared null metrics/spans.  This is the baseline every other repo
  benchmark implicitly measures, so the no-op path costing ~0% is what
  keeps BENCH trajectories comparable across PRs; a null-object
  microbenchmark quantifies it directly (ns per disabled call).
* ``observe=on`` — live registry + tracer.  Acceptance: **<3% wall-clock
  overhead**, asserted on the full-size cell (the --fast cell's per-step
  device work is small enough that scheduler noise exceeds the budget).

Every observed run is parity-asserted byte-for-byte against its
unobserved twin (observe is a pure observer — same discipline as
checkpointing, tests/test_obs.py).

A separate instrumented run with checkpointing enabled exports the
Chrome trace artifact (``artifacts/bench/obs_trace.json`` — load it at
https://ui.perfetto.dev), prints the per-phase time-breakdown table, and
asserts the §16 attribution bar: top-level spans sum to >= 90% of
measured wall time, with step / refill / host-sync / checkpoint-commit
phases all present.

    PYTHONPATH=src python -m benchmarks.bench_obs [--fast]
"""
import dataclasses
import os
import tempfile
import time

import numpy as np

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.data.synthetic_graphs import densifying_graph
from repro.obs import NOOP, NULL_METRIC, coverage, format_table

_T_SWEEP = (1, 16)
_OVERHEAD_BUDGET = 0.03         # acceptance: <3% wall-clock with obs on
_COVERAGE_FLOOR = 0.90          # top-level spans vs wall (full-size cell)
_REQUIRED_SPANS = ("engine.step", "engine.refill", "engine.host_sync",
                   "checkpoint.commit")


def _timed(fn, pre=None):
    if pre is not None:
        pre()
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def _noop_micro(n: int = 200_000) -> dict:
    """ns/call for the disabled path: a null counter inc and a null span
    enter/exit, against an empty-loop control."""
    r = range(n)
    t0 = time.perf_counter()
    for _ in r:
        pass
    empty = time.perf_counter() - t0
    inc = NULL_METRIC.inc
    t0 = time.perf_counter()
    for _ in r:
        inc()
    t_inc = time.perf_counter() - t0
    span = NOOP.tracer.span
    t0 = time.perf_counter()
    for _ in r:
        with span("x"):
            pass
    t_span = time.perf_counter() - t0
    return {"noop_inc_ns": round(max(0.0, t_inc - empty) / n * 1e9, 1),
            "noop_span_ns": round(max(0.0, t_span - empty) / n * 1e9, 1)}


def run(fast: bool = False, rounds: int = 0, out_dir: str = "artifacts/bench",
        tmpdir=None):
    rounds = rounds or (5 if fast else 7)
    own_tmp = tmpdir is None
    if own_tmp:
        tmp = tempfile.TemporaryDirectory(prefix="bench_obs_")
        tmpdir = tmp.name
    try:
        # same long prioritized-run regime as bench_checkpoint: per-step
        # device work large enough that per-step host bookkeeping (what
        # observability adds) is measured against realistic step times
        n, m, batch, pool = ((192, 6000, 16, 512) if fast
                             else (256, 12000, 32, 1024))
        g = densifying_graph(n, m, seed=0)
        comp = make_clique_computation(g)
        base_cfg = EngineConfig(k=5, batch=batch, pool_capacity=pool,
                                max_steps=200_000, spill="host")
        # warm every cell's jit caches, then measure in A-B-A rounds
        # (off, on, off per T).  Overhead is the *median over rounds of
        # on / mean(surrounding offs)*: host clock/load drift — the
        # dominant noise source on shared CI hosts, an off/off control
        # pair alone wobbles ±3%, dwarfing the microseconds of
        # bookkeeping under test — is locally linear, so the symmetric
        # baseline cancels it inside each round, and the median discards
        # rounds a transient hit asymmetrically.  Best-of-N walls are
        # reported alongside for absolute numbers.
        engines = {}
        for T in _T_SWEEP:
            for mode in ("off", "on"):
                eng = Engine(comp, dataclasses.replace(
                    base_cfg, steps_per_sync=T, observe=mode == "on"))
                eng.run()                           # warm the jit caches
                engines[mode, T] = eng
        walls, results = {}, {}
        ratios = {T: [] for T in _T_SWEEP}
        for _ in range(rounds):
            for T in _T_SWEEP:
                a, results["off", T] = _timed(engines["off", T].run)
                b, results["on", T] = _timed(engines["on", T].run)
                c, _ = _timed(engines["off", T].run)
                walls["off", T] = min(walls.get(("off", T), a), a, c)
                walls["on", T] = min(walls.get(("on", T), b), b)
                ratios[T].append(b / ((a + c) / 2))

        rows = []
        for T in _T_SWEEP:
            base_res, obs_res = results["off", T], results["on", T]
            # pure observer: observed runs change nothing
            assert np.array_equal(base_res.result_keys,
                                  obs_res.result_keys), \
                f"T={T}: result keys diverged under observe"
            assert np.array_equal(base_res.result_states,
                                  obs_res.result_states), \
                f"T={T}: result states diverged under observe"
            overhead = float(np.median(ratios[T])) - 1.0
            eng = engines["on", T]
            assert eng.obs.metrics.get(
                "engine_steps_total").value > 0, "observer recorded nothing"
            for mode in ("off", "on"):
                rows.append(dict(
                    workload="clique", spill="host", T=T, observe=mode,
                    wall_s=round(walls[mode, T], 4),
                    steps=results[mode, T].steps,
                    overhead_pct=round(100 * overhead, 2)
                    if mode == "on" else 0.0))
            if not fast:
                assert overhead < _OVERHEAD_BUDGET, \
                    f"T={T}: observe-on overhead {100 * overhead:.2f}% " \
                    f"exceeds the {100 * _OVERHEAD_BUDGET:.0f}% budget"

        micro = _noop_micro()
        # the disabled path must stay in the tens-of-nanoseconds regime —
        # the "~0% when off" half of the §16 budget
        assert micro["noop_inc_ns"] < 1000 and micro["noop_span_ns"] < 2000
        rows.append(dict(workload="noop-micro", **micro))

        # ---- trace-attribution run: observe + checkpointing, exported
        ck_eng = Engine(comp, dataclasses.replace(
            base_cfg, steps_per_sync=16, observe=True, checkpoint_every=64,
            checkpoint_dir=os.path.join(tmpdir, "ckpt")))
        ck_eng.run()                                # warm
        ck_eng.obs.tracer.clear()
        wall, res = _timed(ck_eng.run)
        assert res.refilled > 0, "cell too small: refill phase never ran"
        spans = ck_eng.obs.tracer.spans()
        names = {s[0] for s in spans}
        missing = [s for s in _REQUIRED_SPANS if s not in names]
        assert not missing, f"required phases absent from trace: {missing}"
        cov = coverage(spans, wall)
        if not fast:
            assert cov >= _COVERAGE_FLOOR, \
                f"top-level spans cover {100 * cov:.1f}% of wall " \
                f"(< {100 * _COVERAGE_FLOOR:.0f}%)"
        os.makedirs(out_dir, exist_ok=True)
        trace_path = ck_eng.obs.tracer.export_chrome_trace(
            os.path.join(out_dir, "obs_trace.json"))
        print(f"\nper-phase breakdown (observe=on, checkpoint_every=64, "
              f"T=16):\n{format_table(spans, wall)}")
        print(f"Chrome trace written to {trace_path} "
              f"(load at https://ui.perfetto.dev)")
        rows.append(dict(
            workload="trace", spans_recorded=len(spans),
            coverage_pct=round(100 * cov, 1), wall_s=round(wall, 4),
            trace_path=trace_path))
        return rows
    finally:
        if own_tmp:
            tmp.cleanup()


def main(fast: bool = False):
    rows = run(fast=fast)
    print("\n(top-k parity asserted on every observed row; <3% overhead and"
          " >=90% span coverage asserted full-size)")
    print(f"{'workload':>10} {'T':>3} {'observe':>8} {'steps':>6} "
          f"{'wall s':>8} {'overhead':>9}")
    for r in rows:
        if r["workload"] != "clique":
            continue
        print(f"{r['workload']:>10} {r['T']:>3} {r['observe']:>8} "
              f"{r['steps']:>6} {r['wall_s']:>8.3f} "
              f"{r['overhead_pct']:>8.2f}%")
    micro = next(r for r in rows if r["workload"] == "noop-micro")
    print(f"disabled-path cost: {micro['noop_inc_ns']}ns/inc, "
          f"{micro['noop_span_ns']}ns/span")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    main(fast=ap.parse_args().fast)
