"""Checkpoint overhead benchmark (DESIGN.md §15).

Measures the wall-clock cost of durable runs: the clique workload with
``checkpoint_every`` ∈ {off, 64, 16} against a baseline run with
checkpointing disabled.  Saves are asynchronous (the VPQ capture is
synchronous but cheap; leaf arrays flush on the writer thread), so the
engine should keep stepping while the previous checkpoint commits — the
acceptance bar is **< 5% overhead at checkpoint_every=64**.

Every checkpointed run is parity-asserted byte-for-byte against the
uncheckpointed baseline (checkpointing is a pure observer), and the last
committed step is resumed and re-finalized to prove the artifact on disk
is actually restorable, not just cheap to write.

    PYTHONPATH=src python -m benchmarks.bench_checkpoint [--fast]
"""
import dataclasses
import os
import shutil
import tempfile
import time

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.data.synthetic_graphs import densifying_graph

_EVERY_SWEEP = (0, 64, 16)      # 0 = checkpointing off (baseline)
_OVERHEAD_BUDGET = 0.05         # acceptance: <5% wall-clock at every=64


def _timed(fn, pre=None):
    """One timed call of ``fn``; ``pre`` (untimed) runs first — used to
    clear the previous round's checkpoint dir so directory cleanup never
    pollutes the overhead measurement."""
    if pre is not None:
        pre()
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def run(fast: bool = False, rounds: int = 5, tmpdir=None):
    own_tmp = tmpdir is None
    if own_tmp:
        tmp = tempfile.TemporaryDirectory(prefix="bench_checkpoint_")
        tmpdir = tmp.name
    try:
        # a long prioritized run (hundreds of super-steps) with real spill
        # traffic, sized so per-step device work is in the regime §15
        # targets — saves every 64 steps land tens of ms apart, not every
        # few ms (bench_engine's tiny cells measure the opposite regime)
        n, m, batch, pool = ((192, 6000, 16, 512) if fast
                             else (256, 12000, 32, 1024))
        g = densifying_graph(n, m, seed=0)
        comp = make_clique_computation(g)
        base_cfg = EngineConfig(k=5, batch=batch, pool_capacity=pool,
                                max_steps=200_000, spill="disk",
                                spill_dir=os.path.join(tmpdir, "spill"))
        # warm every config's jit caches first, then measure the sweep in
        # INTERLEAVED rounds (baseline, 64, 16, baseline, 64, 16, ...) so
        # transient system noise hits the baseline and the checkpointed
        # runs alike — on a loaded or single-core host, measuring the
        # baseline once up front biases every overhead number by whatever
        # drift happens afterwards.  Best-of-N per config.
        engines, ckpt_dirs = {}, {}
        for every in _EVERY_SWEEP:
            ckpt_dirs[every] = os.path.join(tmpdir, f"ckpt_every{every}")
            cfg = dataclasses.replace(
                base_cfg, checkpoint_every=every,
                checkpoint_dir=ckpt_dirs[every] if every else None)
            engines[every] = Engine(comp, cfg)
            engines[every].run()                    # warm the jit caches
        walls, results = {}, {}
        for _ in range(rounds):
            for every in _EVERY_SWEEP:
                d = ckpt_dirs[every]
                dt, res = _timed(
                    engines[every].run,
                    pre=lambda d=d: shutil.rmtree(d, ignore_errors=True))
                walls[every] = min(walls.get(every, dt), dt)
                results[every] = res

        rows = []
        base_wall, base_res = walls[0], results[0]
        for every in _EVERY_SWEEP:
            ckpt_dir = ckpt_dirs[every]
            cfg = engines[every].cfg
            wall, res = walls[every], results[every]
            if every == 0:
                overhead = 0.0
                saves = 0
            else:
                # pure observer: durable runs change nothing
                assert np.array_equal(base_res.result_keys,
                                      res.result_keys), \
                    f"every={every}: result keys diverged"
                assert np.array_equal(base_res.result_states,
                                      res.result_states), \
                    f"every={every}: result states diverged"
                overhead = wall / base_wall - 1.0
                mgr = CheckpointManager(ckpt_dir)
                saves = len(mgr.committed_steps())
                assert saves > 0, f"every={every}: nothing committed"
                # the artifact is restorable: resume the newest committed
                # step, run to completion, same top-k
                rcfg = dataclasses.replace(
                    cfg, spill_dir=os.path.join(tmpdir, f"re{every}"))
                reng = Engine(comp, rcfg)
                st = reng.resume(mgr)
                while not st.done and st.steps < rcfg.max_steps:
                    reng.step(st, max_inner=rcfg.max_steps - st.steps)
                rres = reng.finalize(st)
                assert np.array_equal(base_res.result_keys,
                                      rres.result_keys), \
                    f"every={every}: resumed result keys diverged"
            rows.append(dict(
                workload="clique", spill="disk", checkpoint_every=every,
                wall_s=round(wall, 4), steps=res.steps,
                committed_saves=saves,
                overhead_pct=round(100 * overhead, 2)))
        at64 = next(r for r in rows if r["checkpoint_every"] == 64)
        # the <5% acceptance bar is asserted on the full-size workload;
        # the --fast cell's per-step work is small enough that writer-
        # thread scheduling noise alone exceeds the budget
        if not fast:
            assert at64["overhead_pct"] < 100 * _OVERHEAD_BUDGET, \
                f"checkpoint_every=64 overhead {at64['overhead_pct']}% " \
                f"exceeds the {100 * _OVERHEAD_BUDGET}% budget"
        return rows
    finally:
        if own_tmp:
            tmp.cleanup()


def main(fast: bool = False):
    rows = run(fast=fast)
    print("(top-k parity + resumability asserted on every checkpointed row;"
          " <5% overhead asserted at every=64)")
    print(f"{'workload':>8} {'every':>6} {'steps':>6} {'saves':>6} "
          f"{'wall s':>8} {'overhead':>9}")
    for r in rows:
        print(f"{r['workload']:>8} {r['checkpoint_every']:>6} "
              f"{r['steps']:>6} {r['committed_saves']:>6} "
              f"{r['wall_s']:>8.3f} {r['overhead_pct']:>8.2f}%")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    main(fast=ap.parse_args().fast)
