"""Figure 9/10/11 reproduction: clique discovery vs graph density.

Nuri (prioritized + pruned) vs Nuri-NP (targeted only) vs Arabesque-style
exhaustive — candidate subgraphs (the paper's machine-independent metric)
and wall time, on the paper's densification protocol (§6.2: batches of
random edges added to a fixed vertex set).
"""
import time

from repro.core.clique import make_clique_computation
from repro.core.engine import Engine, EngineConfig
from repro.core.exhaustive import (ArabesqueStyleClique,
                                   nuri_np_clique_candidates)
from repro.data.synthetic_graphs import densifying_graph


def run(n: int = 300, edge_steps=(900, 1200, 1500, 1800), seed: int = 0,
        budget: int = 400_000):
    rows = []
    for m in edge_steps:
        g = densifying_graph(n, m, seed)
        comp = make_clique_computation(g)
        t0 = time.time()
        res = Engine(comp, EngineConfig(k=1, batch=64, pool_capacity=16384,
                                        max_steps=200000)).run()
        t_nuri = time.time() - t0

        t0 = time.time()
        np_res = nuri_np_clique_candidates(g, max_candidates=budget)
        t_np = time.time() - t0

        t0 = time.time()
        abq = ArabesqueStyleClique(g, max_candidates=budget).run()
        t_abq = time.time() - t0

        rows.append(dict(
            edges=m, max_clique=int(res.result_keys[0]),
            nuri_candidates=res.candidates, nuri_s=round(t_nuri, 3),
            nurinp_candidates=np_res["candidates"],
            nurinp_completed=np_res["completed"], nurinp_s=round(t_np, 3),
            abq_candidates=abq["candidates"],
            abq_completed=abq["completed"], abq_s=round(t_abq, 3),
        ))
    return rows


def main(fast: bool = False):
    rows = run(n=200, edge_steps=(500, 700, 900) if fast
               else (600, 900, 1200, 1500))
    print(f"{'edges':>6} {'ω':>3} {'Nuri cand':>10} {'NP cand':>10} "
          f"{'Abq cand':>10} {'Nuri s':>8} {'NP s':>8} {'Abq s':>8}")
    for r in rows:
        np_c = f"{r['nurinp_candidates']}" + \
            ("" if r["nurinp_completed"] else "+")
        abq_c = f"{r['abq_candidates']}" + \
            ("" if r["abq_completed"] else "+")
        print(f"{r['edges']:>6} {r['max_clique']:>3} "
              f"{r['nuri_candidates']:>10} {np_c:>10} {abq_c:>10} "
              f"{r['nuri_s']:>8.2f} {r['nurinp_s']:>8.2f} "
              f"{r['abq_s']:>8.2f}")
    return rows


if __name__ == "__main__":
    main()
