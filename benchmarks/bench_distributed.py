"""Sharded-engine benchmark: 1 vs N forced host devices (DESIGN.md §11).

Runs the same clique workload on the single-device engine and on the
sharded engine at increasing shard counts, asserting byte-identical top-k
results at every width, then reports wall-clock speedup plus per-shard
spill / refill / rebalance stats from a skewed workload that forces the
host-side rebalancer to move work.

Device sharding must be configured before JAX initializes, so the harness
entry (:func:`main`) re-executes this file in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``; running the file
directly sets the flag itself:

    PYTHONPATH=src python benchmarks/bench_distributed.py [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_DEVICES = 8
_JSON_MARK = "BENCH-DISTRIBUTED-JSON:"


def _bench(fast: bool) -> dict:
    # deferred imports: JAX must initialize after XLA_FLAGS is set
    import dataclasses

    import numpy as np

    from repro.core.clique import make_clique_computation
    from repro.core.engine import Engine, EngineConfig
    from repro.core.graph import GraphStore
    from repro.data.synthetic_graphs import (decoy_trap_graph,
                                             densifying_graph,
                                             planted_clique_graph)
    from repro.distributed import ShardedEngine

    def best_of(runs, fn):
        best, out = None, None
        for _ in range(runs):
            t0 = time.perf_counter()
            out = fn()
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        return best, out

    n, m = (150, 900) if fast else (300, 2400)
    g = planted_clique_graph(n=n, m=m, clique_size=8, seed=7)
    comp = make_clique_computation(g)
    cfg = EngineConfig(k=4, batch=32, pool_capacity=1024, max_steps=200_000)

    seq_s, ref = best_of(2, Engine(comp, cfg).run)
    rows = []
    for shards in (1, 2, _DEVICES):
        eng = ShardedEngine(comp, dataclasses.replace(cfg, shards=shards))
        wall_s, res = best_of(2, eng.run)
        assert np.array_equal(ref.result_keys, res.result_keys), \
            f"shards={shards}: result keys diverged"
        assert np.array_equal(ref.result_states, res.result_states), \
            f"shards={shards}: result states diverged"
        rows.append(dict(
            shards=shards, wall_s=round(wall_s, 3),
            speedup=round(seq_s / wall_s, 2), steps=res.steps,
            candidates=res.candidates, pruned=res.pruned,
            spilled=res.spilled, refilled=res.refilled,
            rebalanced=res.rebalanced))

    print(f"[bench_distributed] clique n={n} m={m} k={cfg.k} "
          f"(parity vs single-device Engine asserted at every width)")
    print("  note: forced host devices share one CPU, so wall-clock here "
          "validates plumbing, not hardware speedup (see DESIGN.md §11)")
    print(f"  single-device Engine.run : {seq_s:.3f}s")
    print(f"  {'shards':>6} {'wall s':>8} {'speedup':>8} {'steps':>6} "
          f"{'cand':>8} {'spill':>7} {'refill':>7} {'rebal':>6}")
    for r in rows:
        print(f"  {r['shards']:>6} {r['wall_s']:>8.3f} {r['speedup']:>8.2f} "
              f"{r['steps']:>6} {r['candidates']:>8} {r['spilled']:>7} "
              f"{r['refilled']:>7} {r['rebalanced']:>6}")

    # --- skewed workload: hot subtree on one shard, tiny pools -> spill,
    # idle siblings -> the rebalancer must redistribute spilled work
    ns = 96 if fast else 192
    gs = densifying_graph(ns, 5 * ns, seed=3)
    members = np.arange(0, 24, 2)    # clique on even ids = shard 0 of 2
    extra = [(int(u), int(v)) for i, u in enumerate(members)
             for v in members[i + 1:]]
    gs = GraphStore.from_edges(
        ns, np.concatenate([gs.edge_array, np.array(extra, np.int64)]))
    scomp = make_clique_computation(gs)
    scfg = EngineConfig(k=3, batch=8, pool_capacity=64, max_steps=200_000)
    sref = Engine(scomp, scfg).run()
    sres = ShardedEngine(
        scomp, dataclasses.replace(scfg, shards=2)).run()
    assert np.array_equal(sref.result_keys, sres.result_keys)
    assert np.array_equal(sref.result_states, sres.result_states)
    skew = dict(n=ns, shards=2, spilled=sres.spilled,
                refilled=sres.refilled, rebalanced=sres.rebalanced,
                per_shard=sres.per_shard)
    print(f"  skewed n={ns} shards=2: spilled={sres.spilled} "
          f"refilled={sres.refilled} rebalanced={sres.rebalanced} "
          f"per-shard spill={sres.per_shard['spilled']}")
    assert sres.rebalanced > 0, "skewed workload never triggered rebalance"

    # --- staleness-tolerant bound exchange (DESIGN.md §14): a decoy-trap
    # graph 10x+ the parity graph, swept over sync_every K x shards.  The
    # engine's depth-first priority forces the single device to grind the
    # decoy clusters' size-2 tier before its threshold can rise; under
    # round-robin partitioning one shard holds the planted clique and no
    # decoys, reaches the answer in a few super-steps, and the bound
    # exchange lets the rest of the fleet drop the decoy frontier at
    # dequeue / VPQ refill.  Total work is order-dependent (branch-and-
    # bound diversification), so the step-count ratio exceeds the slot
    # ratio — the only way a sharded run can beat the single device on
    # wall clock when all forced host devices share one CPU core.  K is
    # the staleness dial, visible end to end: K=1 pays a collective every
    # step and loses; K~4 wins outright; very large K over-stales (the
    # decoy shards grind on a stale bound) and gives the win back.
    nl, ml, ncl = (1700, 4000, 14) if fast else (3400, 8000, 28)
    gl = decoy_trap_graph(n=nl, m=ml, skew=0.15, clusters=ncl,
                          cluster_size=100, cluster_p=0.141, clique_size=8,
                          stride=_DEVICES, seed=7)
    lcomp = make_clique_computation(gl)
    lcfg = EngineConfig(k=4, batch=8, pool_capacity=64,
                        max_steps=500_000, steps_per_sync=16)
    base_s, lref = best_of(2, Engine(lcomp, lcfg).run)
    stale_rows = []
    for shards in (1, 2, _DEVICES):
        for K in (1, 4, 16):
            eng = ShardedEngine(lcomp, dataclasses.replace(
                lcfg, shards=shards, sync_every=K))
            wall_s, res = best_of(2, eng.run)
            assert np.array_equal(lref.result_keys, res.result_keys), \
                f"shards={shards} K={K}: result keys diverged"
            assert np.array_equal(lref.result_states, res.result_states), \
                f"shards={shards} K={K}: result states diverged"
            stale_rows.append(dict(
                shards=shards, sync_every=K, wall_s=round(wall_s, 3),
                speedup=round(base_s / wall_s, 2), steps=res.steps,
                syncs=res.syncs, host_syncs=res.host_syncs,
                spilled=res.spilled, refilled=res.refilled,
                rebalanced=res.rebalanced))

    best8 = max((r["speedup"] for r in stale_rows
                 if r["shards"] == _DEVICES and r["sync_every"] > 1),
                default=0.0)
    print(f"[bench_distributed] stale-bound K-sweep: decoy-trap clique "
          f"n={nl} m={ml} clusters={ncl} k={lcfg.k} T={lcfg.steps_per_sync} "
          f"(parity vs single-device asserted on every row)")
    print(f"  single-device Engine.run : {base_s:.3f}s")
    print(f"  {'shards':>6} {'K':>3} {'wall s':>8} {'speedup':>8} "
          f"{'steps':>6} {'syncs':>6} {'hsync':>6} {'spill':>7} "
          f"{'rebal':>6}")
    for r in stale_rows:
        print(f"  {r['shards']:>6} {r['sync_every']:>3} "
              f"{r['wall_s']:>8.3f} {r['speedup']:>8.2f} {r['steps']:>6} "
              f"{r['syncs']:>6} {r['host_syncs']:>6} {r['spilled']:>7} "
              f"{r['rebalanced']:>6}")
    print(f"  best 8-shard speedup at K>1: {best8:.2f}x")

    return dict(devices=_DEVICES, n=n, m=m, single_device_s=round(seq_s, 3),
                sharded=rows, skewed=skew,
                stale_sweep=dict(n=nl, m=ml, skew=0.15, clusters=ncl,
                                 steps_per_sync=lcfg.steps_per_sync,
                                 single_device_s=round(base_s, 3),
                                 rows=stale_rows,
                                 best_8shard_speedup=best8))


def main(fast: bool = False) -> dict:
    """Harness entry point: re-exec with forced host devices, parse JSON."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={_DEVICES}"
                        ).strip()
    # device forcing only multiplies CPU-platform devices; pin the platform
    # so a host accelerator doesn't leave jax.devices() short of _DEVICES
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    cmd = [sys.executable, os.path.abspath(__file__), "--json"]
    if fast:
        cmd.append("--fast")
    import subprocess
    res = subprocess.run(cmd, capture_output=True, text=True, timeout=3600,
                         env=env)
    for line in res.stdout.splitlines():
        if not line.startswith(_JSON_MARK):
            print(line)
    if res.returncode:
        sys.stderr.write(res.stderr[-4000:])
        raise RuntimeError("bench_distributed subprocess failed")
    for line in res.stdout.splitlines():
        if line.startswith(_JSON_MARK):
            return json.loads(line[len(_JSON_MARK):])
    raise RuntimeError("bench_distributed produced no JSON result")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="emit a machine-readable result line (harness)")
    args = ap.parse_args()
    # append (not setdefault): a pre-existing XLA_FLAGS value must not
    # silently disable device forcing; for a repeated force flag the last
    # occurrence wins, so the harness-spawned child stays correct too
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={_DEVICES}").strip()
    os.environ["JAX_PLATFORMS"] = "cpu"   # forcing only affects CPU devices
    out = _bench(fast=args.fast)
    if args.json:
        print(_JSON_MARK + json.dumps(out))
